//! Neural Architecture Search (paper §5.3): TPE search strategy over the
//! KWS conv space, performance estimation via surrogate or real PJRT
//! training, and Pareto-frontier selection on (accuracy, MFP_ops) — the
//! integrated solution of [53] that produced Tables 4 and 5.

pub mod evaluator;
pub mod flops;
pub mod pareto;
pub mod space;
pub mod tpe;

use evaluator::{ArchEvaluator, Evaluation};
use space::KwsArch;
use tpe::{Tpe, TpeConfig};

#[derive(Debug, Clone)]
pub struct NasConfig {
    pub trials: usize,
    pub ds: bool,
    /// Objective trade-off: maximize acc - lambda * log2(mflops).
    pub lambda: f64,
    pub seed: u64,
}

impl Default for NasConfig {
    fn default() -> Self {
        NasConfig { trials: 120, ds: false, lambda: 0.35, seed: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct Candidate {
    pub arch: KwsArch,
    pub eval: Evaluation,
}

#[derive(Debug, Clone)]
pub struct NasOutcome {
    pub candidates: Vec<Candidate>,
    /// Indices into `candidates` on the (accuracy, mflops) Pareto frontier,
    /// ascending mflops.
    pub frontier: Vec<usize>,
}

/// Run the search: TPE proposes, the evaluator scores, Pareto selects.
pub fn search(
    cfg: &NasConfig,
    eval: &mut dyn ArchEvaluator,
) -> Result<NasOutcome, String> {
    let mut tpe = Tpe::new(
        KwsArch::cardinalities(),
        TpeConfig { seed: cfg.seed, ..Default::default() },
    );
    let mut candidates: Vec<Candidate> = Vec::with_capacity(cfg.trials);
    let mut seen = std::collections::HashSet::new();
    for t in 0..cfg.trials {
        let idx = tpe.suggest();
        let arch = KwsArch::decode(cfg.ds, &idx);
        if !seen.insert(arch.clone()) {
            // duplicate proposal: feed back the known objective
            if let Some(c) = candidates.iter().find(|c| c.arch == arch) {
                let obj = c.eval.accuracy - cfg.lambda * c.eval.mflops.log2();
                tpe.observe(idx, obj);
            }
            continue;
        }
        let e = eval.evaluate(&arch)?;
        let obj = e.accuracy - cfg.lambda * e.mflops.max(1e-3).log2();
        if t % 20 == 0 {
            eprintln!(
                "  trial {t:>4}: acc {:.2}% {:.1} MFLOPs obj {obj:.2} [{}]",
                e.accuracy,
                e.mflops,
                arch.describe()
            );
        }
        tpe.observe(idx, obj);
        candidates.push(Candidate { arch, eval: e });
    }
    let pts: Vec<(f64, f64)> = candidates
        .iter()
        .map(|c| (c.eval.accuracy, c.eval.mflops))
        .collect();
    let frontier = pareto::frontier(&pts);
    Ok(NasOutcome { candidates, frontier })
}

impl NasOutcome {
    /// Frontier candidates as (describe, acc, mflops, size_kb) rows.
    pub fn frontier_rows(&self) -> Vec<(String, f64, f64, f64)> {
        self.frontier
            .iter()
            .map(|&i| {
                let c = &self.candidates[i];
                (
                    c.arch.describe(),
                    c.eval.accuracy,
                    c.eval.mflops,
                    c.eval.size_kb,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use evaluator::Surrogate;

    #[test]
    fn nas_frontier_dominates_the_seed() {
        let cfg = NasConfig { trials: 150, ds: false, lambda: 0.35, seed: 1 };
        let out = search(&cfg, &mut Surrogate).unwrap();
        assert!(!out.frontier.is_empty());
        let seed_arch = KwsArch { ds: false, convs: vec![(3, 100); 6] };
        let seed_acc = evaluator::surrogate_accuracy(&seed_arch);
        let seed_mf = flops::mflops(&seed_arch);
        // paper §8.1: NAS discovers models that dominate the seed
        let dominated = out.frontier.iter().any(|&i| {
            let c = &out.candidates[i];
            c.eval.accuracy >= seed_acc && c.eval.mflops < seed_mf
        });
        assert!(dominated, "no frontier candidate dominates the seed");
        // frontier is sorted by ascending flops with ascending accuracy
        let rows = out.frontier_rows();
        for w in rows.windows(2) {
            assert!(w[0].2 <= w[1].2);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn ds_search_produces_small_models() {
        let cfg = NasConfig { trials: 100, ds: true, lambda: 0.5, seed: 2 };
        let out = search(&cfg, &mut Surrogate).unwrap();
        let rows = out.frontier_rows();
        // paper Table 5: DS models in the ~7-12 MFLOP band exist
        assert!(
            rows.iter().any(|r| r.2 < 20.0),
            "no small DS model found: {rows:?}"
        );
    }

    #[test]
    fn duplicate_proposals_do_not_crash() {
        let cfg = NasConfig { trials: 300, ds: false, lambda: 0.35, seed: 3 };
        let out = search(&cfg, &mut Surrogate).unwrap();
        assert!(out.candidates.len() <= 300);
        // uniqueness
        let set: std::collections::HashSet<_> =
            out.candidates.iter().map(|c| c.arch.clone()).collect();
        assert_eq!(set.len(), out.candidates.len());
    }
}
