//! Tools (paper §3.2): software components performing one pipeline function,
//! packaged with declared input/output ports over artifact formats. The
//! paper isolates tools in Docker containers with an HTTP API; here each
//! tool runs with a mediated context that only exposes its declared inputs
//! and a staging directory for its declared outputs (DESIGN.md §3 documents
//! the container -> mediated-context substitution; the *interface* contract
//! is identical).

use super::artifact::{ArtifactStore, PortMap};
use crate::runtime::EngineHandle;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// A declared port: name + required artifact format.
#[derive(Debug, Clone, PartialEq)]
pub struct Port {
    pub name: String,
    pub format: String,
}

impl Port {
    pub fn new(name: &str, format: &str) -> Port {
        Port { name: name.to_string(), format: format.to_string() }
    }
}

/// Execution context handed to a tool: resolved input artifact directories,
/// staging directories for outputs, parameters, and the shared PJRT engine
/// (the "GPU of the container").
pub struct ToolCtx<'a> {
    pub store: &'a ArtifactStore,
    pub params: Json,
    pub inputs: BTreeMap<String, PathBuf>,
    pub outputs: BTreeMap<String, PathBuf>,
    pub engine: Option<EngineHandle>,
    pub log: Vec<String>,
}

impl ToolCtx<'_> {
    pub fn input(&self, port: &str) -> Result<&PathBuf, String> {
        self.inputs.get(port).ok_or_else(|| format!("input port '{port}' not bound"))
    }
    pub fn output(&self, port: &str) -> Result<&PathBuf, String> {
        self.outputs.get(port).ok_or_else(|| format!("output port '{port}' not bound"))
    }
    pub fn engine(&self) -> Result<&EngineHandle, String> {
        self.engine.as_ref().ok_or_else(|| "tool requires the PJRT engine".to_string())
    }
    pub fn param_str(&self, key: &str, default: &str) -> String {
        self.params.get(key).as_str().unwrap_or(default).to_string()
    }
    pub fn param_usize(&self, key: &str, default: usize) -> usize {
        self.params.get(key).as_usize().unwrap_or(default)
    }
    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.params.get(key).as_f64().unwrap_or(default)
    }
    pub fn info(&mut self, msg: impl Into<String>) {
        let msg = msg.into();
        eprintln!("    [tool] {msg}");
        self.log.push(msg);
    }
}

/// A pipeline tool. `image` is the container-image metadata the paper's
/// docker packaging would use (recorded for provenance).
pub trait Tool: Send + Sync {
    fn name(&self) -> &str;
    fn image(&self) -> String {
        format!("bonseyes/{}:latest", self.name())
    }
    fn inputs(&self) -> Vec<Port>;
    fn outputs(&self) -> Vec<Port>;
    /// Extra JSON recorded on each produced artifact.
    fn provenance(&self, ctx: &ToolCtx) -> Json {
        Json::obj(vec![
            ("image", Json::str(self.image())),
            ("params", ctx.params.clone()),
        ])
    }
    fn run(&self, ctx: &mut ToolCtx) -> Result<(), String>;
}

/// Tool registry: the catalog a workflow resolves tool names against.
#[derive(Default)]
pub struct Registry {
    tools: BTreeMap<String, Arc<dyn Tool>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, tool: Arc<dyn Tool>) {
        self.tools.insert(tool.name().to_string(), tool);
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn Tool>> {
        self.tools.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.tools.keys().cloned().collect()
    }

    /// Tools whose input/output signature matches (interchangeability probe —
    /// the paper's claim that same-port tools are swappable).
    pub fn interchangeable_with(&self, name: &str) -> Vec<String> {
        let Some(t) = self.get(name) else { return Vec::new() };
        let (ti, to) = (t.inputs(), t.outputs());
        self.tools
            .values()
            .filter(|o| o.name() != name && o.inputs() == ti && o.outputs() == to)
            .map(|o| o.name().to_string())
            .collect()
    }
}

/// Execute one tool invocation: resolve inputs, stage outputs, run, commit.
pub fn invoke(
    store: &ArtifactStore,
    tool: &dyn Tool,
    params: Json,
    input_bindings: &PortMap,
    output_bindings: &PortMap,
    engine: Option<EngineHandle>,
) -> Result<Vec<String>, String> {
    // resolve + type-check inputs
    let mut inputs = BTreeMap::new();
    for port in tool.inputs() {
        let artifact = input_bindings
            .get(&port.name)
            .ok_or_else(|| format!("{}: input '{}' unbound", tool.name(), port.name))?;
        let meta = store
            .meta(artifact)
            .ok_or_else(|| format!("{}: input artifact '{artifact}' missing", tool.name()))?;
        if meta.format != port.format {
            return Err(format!(
                "{}: input '{}' expects format {} but artifact '{artifact}' is {}",
                tool.name(),
                port.name,
                port.format,
                meta.format
            ));
        }
        inputs.insert(port.name.clone(), store.dir(artifact));
    }
    // stage outputs
    let mut outputs = BTreeMap::new();
    for port in tool.outputs() {
        let artifact = output_bindings
            .get(&port.name)
            .ok_or_else(|| format!("{}: output '{}' unbound", tool.name(), port.name))?;
        let dir = store.stage(artifact).map_err(|e| e.to_string())?;
        outputs.insert(port.name.clone(), dir);
    }
    let mut ctx = ToolCtx { store, params, inputs, outputs, engine, log: Vec::new() };
    tool.run(&mut ctx)?;
    // commit outputs with provenance
    let prov = tool.provenance(&ctx);
    for port in tool.outputs() {
        let artifact = &output_bindings[&port.name];
        store
            .commit(artifact, &port.format, tool.name(), prov.clone())
            .map_err(|e| e.to_string())?;
    }
    Ok(ctx.log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::artifact::formats;

    struct MakeData;
    impl Tool for MakeData {
        fn name(&self) -> &str {
            "make-data"
        }
        fn inputs(&self) -> Vec<Port> {
            vec![]
        }
        fn outputs(&self) -> Vec<Port> {
            vec![Port::new("data", formats::AUDIO_DATASET)]
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<(), String> {
            let n = ctx.param_usize("n", 3);
            std::fs::write(ctx.output("data")?.join("data.txt"), format!("{n}"))
                .map_err(|e| e.to_string())?;
            ctx.info(format!("made {n}"));
            Ok(())
        }
    }

    struct Consume;
    impl Tool for Consume {
        fn name(&self) -> &str {
            "consume"
        }
        fn inputs(&self) -> Vec<Port> {
            vec![Port::new("data", formats::AUDIO_DATASET)]
        }
        fn outputs(&self) -> Vec<Port> {
            vec![Port::new("report", formats::REPORT)]
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<(), String> {
            let s = std::fs::read_to_string(ctx.input("data")?.join("data.txt"))
                .map_err(|e| e.to_string())?;
            std::fs::write(ctx.output("report")?.join("report.json"),
                           format!("{{\"n\": {s}}}"))
                .map_err(|e| e.to_string())
        }
    }

    fn store() -> ArtifactStore {
        let d = std::env::temp_dir().join(format!(
            "bonseyes-tool-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        ArtifactStore::open(d).unwrap()
    }

    #[test]
    fn invoke_chain_passes_artifacts() {
        let store = store();
        let mut out1 = PortMap::new();
        out1.insert("data".into(), "ds".into());
        invoke(&store, &MakeData, Json::obj(vec![("n", Json::num(7.0))]),
               &PortMap::new(), &out1, None)
            .unwrap();
        let mut in2 = PortMap::new();
        in2.insert("data".into(), "ds".into());
        let mut out2 = PortMap::new();
        out2.insert("report".into(), "rep".into());
        invoke(&store, &Consume, Json::Null, &in2, &out2, None).unwrap();
        let rep = std::fs::read_to_string(store.dir("rep").join("report.json")).unwrap();
        assert!(rep.contains('7'));
        assert_eq!(store.meta("rep").unwrap().format, formats::REPORT);
        assert_eq!(store.meta("rep").unwrap().producer, "consume");
    }

    #[test]
    fn format_mismatch_is_rejected() {
        let store = store();
        // stage an artifact with the wrong format
        store.stage("bad").unwrap();
        store.commit("bad", formats::MODEL, "x", Json::Null).unwrap();
        let mut in2 = PortMap::new();
        in2.insert("data".into(), "bad".into());
        let mut out2 = PortMap::new();
        out2.insert("report".into(), "rep".into());
        let err = invoke(&store, &Consume, Json::Null, &in2, &out2, None).unwrap_err();
        assert!(err.contains("expects format"), "{err}");
    }

    #[test]
    fn missing_input_is_rejected() {
        let store = store();
        let err = invoke(&store, &Consume, Json::Null, &PortMap::new(),
                         &PortMap::new(), None)
            .unwrap_err();
        assert!(err.contains("unbound"));
    }

    #[test]
    fn registry_finds_interchangeable_tools() {
        let mut reg = Registry::new();
        reg.register(Arc::new(MakeData));
        reg.register(Arc::new(Consume));
        assert!(reg.interchangeable_with("make-data").is_empty());
        assert_eq!(reg.names(), vec!["consume", "make-data"]);
    }
}
