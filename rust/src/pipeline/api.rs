//! REST control API over the pipeline (paper §3.2: "a high-level HTTP API is
//! defined to control the workflows and tools"). Workflows submitted via
//! POST run asynchronously; status is polled by id.

use super::artifact::ArtifactStore;
use super::tool::Registry;
use super::workflow::{run as run_workflow, RunReport, Workflow};
use crate::http::{Response, Router, Server};
use crate::runtime::EngineHandle;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

#[derive(Debug, Clone)]
pub enum RunState {
    Running,
    Done(RunReport),
    Failed(String),
}

pub struct PipelineService {
    pub store: Arc<ArtifactStore>,
    pub registry: Arc<Registry>,
    pub engine: Option<EngineHandle>,
    /// Run states plus a condvar notified whenever a run finishes, so
    /// `wait` parks instead of sleep-polling.
    runs: Arc<(Mutex<HashMap<u64, RunState>>, Condvar)>,
    next_id: AtomicU64,
}

impl PipelineService {
    pub fn new(
        store: Arc<ArtifactStore>,
        registry: Arc<Registry>,
        engine: Option<EngineHandle>,
    ) -> Arc<PipelineService> {
        Arc::new(PipelineService {
            store,
            registry,
            engine,
            runs: Arc::new((Mutex::new(HashMap::new()), Condvar::new())),
            next_id: AtomicU64::new(1),
        })
    }

    /// Submit a workflow for asynchronous execution; returns the run id.
    pub fn submit(self: &Arc<Self>, wf: Workflow, force: bool) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.runs.0.lock().unwrap().insert(id, RunState::Running);
        let me = Arc::clone(self);
        std::thread::spawn(move || {
            let result = run_workflow(&wf, &me.registry, &me.store, me.engine.clone(), force);
            let state = match result {
                Ok(rep) => RunState::Done(rep),
                Err(e) => RunState::Failed(e),
            };
            let (lock, cvar) = &*me.runs;
            lock.lock().unwrap().insert(id, state);
            cvar.notify_all();
        });
        id
    }

    pub fn state(&self, id: u64) -> Option<RunState> {
        self.runs.0.lock().unwrap().get(&id).cloned()
    }

    /// Block until a run finishes (test/CLI helper): parks on the condvar
    /// signalled at run completion rather than sleep-polling.
    pub fn wait(&self, id: u64) -> RunState {
        let (lock, cvar) = &*self.runs;
        let mut runs = lock.lock().unwrap();
        loop {
            match runs.get(&id) {
                Some(RunState::Running) | None => {
                    runs = cvar.wait(runs).unwrap();
                }
                Some(s) => return s.clone(),
            }
        }
    }

    /// Build the HTTP router exposing the control API.
    pub fn router(self: &Arc<Self>) -> Router {
        let mut r = Router::new();
        let me = Arc::clone(self);
        r.add("GET", "/v1/tools", move |_req, _| {
            let tools: Vec<Json> = me
                .registry
                .names()
                .iter()
                .map(|n| {
                    let t = me.registry.get(n).unwrap();
                    Json::obj(vec![
                        ("name", Json::str(n.clone())),
                        ("image", Json::str(t.image())),
                        (
                            "inputs",
                            Json::arr(
                                t.inputs()
                                    .iter()
                                    .map(|p| {
                                        Json::obj(vec![
                                            ("port", Json::str(p.name.clone())),
                                            ("format", Json::str(p.format.clone())),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        (
                            "outputs",
                            Json::arr(
                                t.outputs()
                                    .iter()
                                    .map(|p| {
                                        Json::obj(vec![
                                            ("port", Json::str(p.name.clone())),
                                            ("format", Json::str(p.format.clone())),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        (
                            "interchangeable_with",
                            Json::arr(
                                me.registry
                                    .interchangeable_with(n)
                                    .into_iter()
                                    .map(Json::str)
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect();
            Response::json(200, &Json::arr(tools))
        });
        let me = Arc::clone(self);
        r.add("GET", "/v1/artifacts", move |_req, _| {
            let arts: Vec<Json> = me
                .store
                .list()
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("name", Json::str(m.name.clone())),
                        ("format", Json::str(m.format.clone())),
                        ("producer", Json::str(m.producer.clone())),
                        ("hash", Json::str(format!("{:016x}", m.content_hash))),
                    ])
                })
                .collect();
            Response::json(200, &Json::arr(arts))
        });
        let me = Arc::clone(self);
        r.add("GET", "/v1/artifacts/:name", move |_req, params| {
            match me.store.meta(&params["name"]) {
                None => Response::not_found(),
                Some(m) => Response::json(
                    200,
                    &Json::obj(vec![
                        ("name", Json::str(m.name)),
                        ("format", Json::str(m.format)),
                        ("producer", Json::str(m.producer)),
                        ("created_unix", Json::num(m.created_unix as f64)),
                        ("verified", Json::Bool(me.store.verify(&params["name"]))),
                        ("extra", m.extra),
                    ]),
                ),
            }
        });
        let me = Arc::clone(self);
        r.add("DELETE", "/v1/artifacts/:name", move |_req, params| {
            match me.store.delete(&params["name"]) {
                Ok(()) => Response::json(200, &Json::obj(vec![("deleted", Json::Bool(true))])),
                Err(_) => Response::not_found(),
            }
        });
        let me = Arc::clone(self);
        r.add("POST", "/v1/workflows", move |req, _| {
            let body = match req.json() {
                Ok(b) => b,
                Err(e) => return Response::bad_request(&e),
            };
            let wf = match Workflow::from_json(&body) {
                Ok(w) => w,
                Err(e) => return Response::bad_request(&e),
            };
            if let Err(e) = wf.validate(&me.registry, &me.store) {
                return Response::bad_request(&e);
            }
            let force = req.query_get("force") == Some("1");
            let id = me.submit(wf, force);
            Response::json(202, &Json::obj(vec![("run_id", Json::num(id as f64))]))
        });
        let me = Arc::clone(self);
        r.add("GET", "/v1/workflows/:id", move |_req, params| {
            let id: u64 = match params["id"].parse() {
                Ok(i) => i,
                Err(_) => return Response::bad_request("bad id"),
            };
            match me.state(id) {
                None => Response::not_found(),
                Some(RunState::Running) => Response::json(
                    200,
                    &Json::obj(vec![("status", Json::str("running"))]),
                ),
                Some(RunState::Failed(e)) => Response::json(
                    200,
                    &Json::obj(vec![
                        ("status", Json::str("failed")),
                        ("error", Json::str(e)),
                    ]),
                ),
                Some(RunState::Done(rep)) => Response::json(
                    200,
                    &Json::obj(vec![
                        ("status", Json::str("done")),
                        ("report", rep.to_json()),
                    ]),
                ),
            }
        });
        r
    }

    /// Serve the API; returns the bound server.
    pub fn serve(self: &Arc<Self>, addr: &str) -> std::io::Result<Server> {
        Server::serve(addr, self.router(), 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::client;
    use crate::pipeline::artifact::formats;
    use crate::pipeline::tool::{Port, Tool, ToolCtx};

    struct Producer;
    impl Tool for Producer {
        fn name(&self) -> &str {
            "producer"
        }
        fn inputs(&self) -> Vec<Port> {
            vec![]
        }
        fn outputs(&self) -> Vec<Port> {
            vec![Port::new("out", formats::REPORT)]
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<(), String> {
            std::fs::write(ctx.output("out")?.join("x.json"), "{}").map_err(|e| e.to_string())
        }
    }

    fn service() -> Arc<PipelineService> {
        let d = std::env::temp_dir().join(format!(
            "bonseyes-api-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        let store = Arc::new(ArtifactStore::open(d).unwrap());
        let mut reg = Registry::new();
        reg.register(Arc::new(Producer));
        PipelineService::new(store, Arc::new(reg), None)
    }

    #[test]
    fn rest_workflow_lifecycle() {
        let svc = service();
        let mut server = svc.serve("127.0.0.1:0").unwrap();
        let base = format!("http://{}", server.addr);

        let tools = client::get(&format!("{base}/v1/tools")).unwrap();
        assert_eq!(tools.status, 200);
        assert_eq!(tools.json().unwrap().at(0).get("name").as_str(), Some("producer"));

        let wf = Json::parse(
            r#"{"name":"w","steps":[{"tool":"producer","outputs":{"out":"art1"}}]}"#,
        )
        .unwrap();
        let resp = client::post_json(&format!("{base}/v1/workflows"), &wf).unwrap();
        assert_eq!(resp.status, 202);
        let id = resp.json().unwrap().get("run_id").as_i64().unwrap() as u64;
        let state = svc.wait(id);
        assert!(matches!(state, RunState::Done(_)));

        let st = client::get(&format!("{base}/v1/workflows/{id}")).unwrap();
        assert_eq!(st.json().unwrap().get("status").as_str(), Some("done"));

        let arts = client::get(&format!("{base}/v1/artifacts")).unwrap();
        assert_eq!(arts.json().unwrap().at(0).get("name").as_str(), Some("art1"));

        let one = client::get(&format!("{base}/v1/artifacts/art1")).unwrap();
        assert_eq!(one.json().unwrap().get("verified").as_bool(), Some(true));

        let del = client::delete(&format!("{base}/v1/artifacts/art1")).unwrap();
        assert_eq!(del.status, 200);
        server.stop();
    }

    #[test]
    fn invalid_workflow_is_rejected_with_400() {
        let svc = service();
        let mut server = svc.serve("127.0.0.1:0").unwrap();
        let base = format!("http://{}", server.addr);
        let wf = Json::parse(r#"{"name":"w","steps":[{"tool":"ghost"}]}"#).unwrap();
        let resp = client::post_json(&format!("{base}/v1/workflows"), &wf).unwrap();
        assert_eq!(resp.status, 400);
        server.stop();
    }
}
