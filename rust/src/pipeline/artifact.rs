//! Artifact store (paper §3.2): artifacts are the products of tool
//! executions — datasets, feature tensors, trained models, reports — stored
//! with a declared *format*, provenance, and a content hash. Tools declare
//! their inputs/outputs against these formats, which is what makes tools
//! with matching ports interchangeable (the paper's modularity claim).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

/// Standard artifact formats (the paper's "collection of standard formats
/// that define on-disk serialization"). Extendable: formats are open strings,
/// these are the ones the built-in tools speak.
pub mod formats {
    /// Raw audio dataset: BTA container of waveforms + labels.
    pub const AUDIO_DATASET: &str = "bonseyes/audio-dataset";
    /// MFCC feature tensor set: BTA container of features + labels.
    pub const FEATURE_SET: &str = "bonseyes/feature-set";
    /// Trained model: flat f32 params/stats blobs + metadata.
    pub const MODEL: &str = "bonseyes/kws-model";
    /// JSON benchmark/accuracy report.
    pub const REPORT: &str = "bonseyes/report";
    /// Deployed AI application (LNE model + assignment).
    pub const AI_APP: &str = "bonseyes/ai-app";
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub format: String,
    pub producer: String,
    pub created_unix: u64,
    pub content_hash: u64,
    pub extra: Json,
}

impl ArtifactMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("format", Json::str(self.format.clone())),
            ("producer", Json::str(self.producer.clone())),
            ("created_unix", Json::num(self.created_unix as f64)),
            ("content_hash", Json::str(format!("{:016x}", self.content_hash))),
            ("extra", self.extra.clone()),
        ])
    }

    fn from_json(v: &Json) -> Option<ArtifactMeta> {
        Some(ArtifactMeta {
            name: v.get("name").as_str()?.to_string(),
            format: v.get("format").as_str()?.to_string(),
            producer: v.get("producer").as_str().unwrap_or("").to_string(),
            created_unix: v.get("created_unix").as_usize().unwrap_or(0) as u64,
            content_hash: u64::from_str_radix(
                v.get("content_hash").as_str().unwrap_or("0"),
                16,
            )
            .unwrap_or(0),
            extra: v.get("extra").clone(),
        })
    }
}

/// Filesystem-backed artifact store. Each artifact is a directory:
/// `<root>/<name>/{meta.json, payload files...}`.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    pub fn open(root: impl AsRef<Path>) -> std::io::Result<ArtifactStore> {
        std::fs::create_dir_all(root.as_ref())?;
        Ok(ArtifactStore { root: root.as_ref().to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn dir(&self, name: &str) -> PathBuf {
        self.root.join(sanitize(name))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.dir(name).join("meta.json").exists()
    }

    /// Begin staging an artifact: returns a fresh payload directory the tool
    /// writes into; `commit` finalizes it (hash + metadata).
    pub fn stage(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = self.dir(name);
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        std::fs::create_dir_all(&dir)?;
        Ok(dir)
    }

    pub fn commit(
        &self,
        name: &str,
        format: &str,
        producer: &str,
        extra: Json,
    ) -> std::io::Result<ArtifactMeta> {
        let dir = self.dir(name);
        let hash = hash_dir(&dir)?;
        let meta = ArtifactMeta {
            name: name.to_string(),
            format: format.to_string(),
            producer: producer.to_string(),
            created_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            content_hash: hash,
            extra,
        };
        std::fs::write(dir.join("meta.json"), meta.to_json().to_string())?;
        Ok(meta)
    }

    pub fn meta(&self, name: &str) -> Option<ArtifactMeta> {
        let text = std::fs::read_to_string(self.dir(name).join("meta.json")).ok()?;
        ArtifactMeta::from_json(&Json::parse(&text).ok()?)
    }

    pub fn list(&self) -> Vec<ArtifactMeta> {
        let mut out = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.root) {
            for e in entries.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(m) = self.meta(name) {
                        out.push(m);
                    }
                }
            }
        }
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    pub fn delete(&self, name: &str) -> std::io::Result<()> {
        std::fs::remove_dir_all(self.dir(name))
    }

    /// Verify an artifact's payload against its recorded hash.
    pub fn verify(&self, name: &str) -> bool {
        match self.meta(name) {
            None => false,
            Some(m) => hash_dir(&self.dir(name)).map(|h| h == m.content_hash).unwrap_or(false),
        }
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect()
}

/// FNV-1a over sorted payload file names + contents (meta.json excluded).
fn hash_dir(dir: &Path) -> std::io::Result<u64> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)?
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.file_name().map(|n| n != "meta.json").unwrap_or(true) && p.is_file())
        .collect();
    files.sort();
    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for f in files {
        eat(f.file_name().unwrap().to_string_lossy().as_bytes());
        eat(&std::fs::read(&f)?);
    }
    Ok(h)
}

/// Typed helpers for common payloads.
pub fn write_json(dir: &Path, file: &str, v: &Json) -> std::io::Result<()> {
    std::fs::write(dir.join(file), v.to_string())
}

pub fn read_json(dir: &Path, file: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(dir.join(file)).map_err(|e| e.to_string())?;
    Json::parse(&text).map_err(|e| e.to_string())
}

/// Minimal ordered-map helper used by tools.
pub type PortMap = BTreeMap<String, String>;

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "bonseyes-store-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn stage_commit_roundtrip() {
        let store = ArtifactStore::open(tmp()).unwrap();
        let dir = store.stage("ds1").unwrap();
        std::fs::write(dir.join("data.bin"), b"hello").unwrap();
        let meta = store
            .commit("ds1", formats::AUDIO_DATASET, "tool-x", Json::Null)
            .unwrap();
        assert!(store.exists("ds1"));
        assert_eq!(store.meta("ds1").unwrap().format, formats::AUDIO_DATASET);
        assert_eq!(meta.producer, "tool-x");
        assert!(store.verify("ds1"));
        assert_eq!(store.list().len(), 1);
    }

    #[test]
    fn tamper_detection() {
        let store = ArtifactStore::open(tmp()).unwrap();
        let dir = store.stage("a").unwrap();
        std::fs::write(dir.join("p.bin"), b"payload").unwrap();
        store.commit("a", formats::MODEL, "t", Json::Null).unwrap();
        std::fs::write(store.dir("a").join("p.bin"), b"tampered").unwrap();
        assert!(!store.verify("a"));
    }

    #[test]
    fn restage_replaces() {
        let store = ArtifactStore::open(tmp()).unwrap();
        let dir = store.stage("x").unwrap();
        std::fs::write(dir.join("1.bin"), b"one").unwrap();
        store.commit("x", formats::REPORT, "t", Json::Null).unwrap();
        let dir = store.stage("x").unwrap();
        assert!(!dir.join("1.bin").exists(), "stage must clear old payload");
    }

    #[test]
    fn sanitize_rejects_traversal() {
        let store = ArtifactStore::open(tmp()).unwrap();
        let d = store.dir("../evil");
        assert!(d.starts_with(store.root()));
    }
}
