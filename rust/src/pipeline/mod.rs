//! The Bonseyes AI-pipeline framework (paper §3): tools, artifacts and
//! workflows, plus the HTTP control API. The concrete tools (data
//! ingestion, training, deployment, IoT) live in their domain modules and
//! register here.

pub mod api;
pub mod artifact;
pub mod tool;
pub mod workflow;

pub use artifact::{formats, ArtifactMeta, ArtifactStore};
pub use tool::{invoke, Port, Registry, Tool, ToolCtx};
pub use workflow::{run, RunReport, Step, Workflow};
