//! Workflows (paper §3.2): declarative JSON pipeline descriptions listing
//! the tools to run and the artifacts to create. The executor resolves each
//! step's tool against the registry, checks artifact availability, skips
//! steps whose outputs already exist (incremental re-runs), and records a
//! run log.
//!
//! Workflow JSON:
//! ```json
//! { "name": "kws-e2e",
//!   "steps": [
//!     { "tool": "speech-commands-import", "params": {"samples": 2000},
//!       "inputs": {}, "outputs": {"data": "raw-speech"} },
//!     { "tool": "mfcc-features",
//!       "inputs": {"data": "raw-speech"}, "outputs": {"features": "mfcc"} }
//!   ] }
//! ```

use super::artifact::{ArtifactStore, PortMap};
use super::tool::{invoke, Registry};
use crate::runtime::EngineHandle;
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Step {
    pub tool: String,
    pub params: Json,
    pub inputs: PortMap,
    pub outputs: PortMap,
}

#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: String,
    pub steps: Vec<Step>,
}

impl Workflow {
    pub fn parse(text: &str) -> Result<Workflow, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Workflow, String> {
        let name = v.get("name").as_str().unwrap_or("workflow").to_string();
        let mut steps = Vec::new();
        for s in v.get("steps").as_arr().ok_or("workflow needs steps[]")? {
            let tool = s.get("tool").as_str().ok_or("step needs tool")?.to_string();
            let port_map = |key: &str| -> PortMap {
                s.get(key)
                    .as_obj()
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            steps.push(Step {
                tool,
                params: s.get("params").clone(),
                inputs: port_map("inputs"),
                outputs: port_map("outputs"),
            });
        }
        Ok(Workflow { name, steps })
    }

    /// Static validation against a registry: tools exist, ports covered,
    /// inputs are produced by earlier steps or pre-existing artifacts.
    pub fn validate(&self, reg: &Registry, store: &ArtifactStore) -> Result<(), String> {
        let mut produced: Vec<String> = Vec::new();
        for (i, step) in self.steps.iter().enumerate() {
            let tool = reg
                .get(&step.tool)
                .ok_or_else(|| format!("step {i}: unknown tool '{}'", step.tool))?;
            for port in tool.inputs() {
                let artifact = step
                    .inputs
                    .get(&port.name)
                    .ok_or_else(|| format!("step {i} ({}): input '{}' unbound", step.tool, port.name))?;
                if !produced.contains(artifact) && !store.exists(artifact) {
                    return Err(format!(
                        "step {i} ({}): input artifact '{artifact}' is neither produced by an earlier step nor present in the store",
                        step.tool
                    ));
                }
            }
            for port in tool.outputs() {
                let artifact = step
                    .outputs
                    .get(&port.name)
                    .ok_or_else(|| format!("step {i} ({}): output '{}' unbound", step.tool, port.name))?;
                produced.push(artifact.clone());
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct StepResult {
    pub tool: String,
    pub skipped: bool,
    pub seconds: f64,
    pub log: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct RunReport {
    pub workflow: String,
    pub steps: Vec<StepResult>,
    pub seconds: f64,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workflow", Json::str(self.workflow.clone())),
            ("seconds", Json::num(self.seconds)),
            (
                "steps",
                Json::arr(
                    self.steps
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("tool", Json::str(s.tool.clone())),
                                ("skipped", Json::Bool(s.skipped)),
                                ("seconds", Json::num(s.seconds)),
                                (
                                    "log",
                                    Json::arr(s.log.iter().map(|l| Json::str(l.clone())).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Execute a workflow. `force` re-runs steps whose outputs already exist.
pub fn run(
    wf: &Workflow,
    reg: &Registry,
    store: &ArtifactStore,
    engine: Option<EngineHandle>,
    force: bool,
) -> Result<RunReport, String> {
    wf.validate(reg, store)?;
    let t_all = Instant::now();
    let mut results = Vec::new();
    for step in &wf.steps {
        let tool = reg.get(&step.tool).expect("validated");
        let have_all = !step.outputs.is_empty()
            && step.outputs.values().all(|a| store.exists(a));
        if have_all && !force {
            eprintln!("  [skip] {} (outputs exist)", step.tool);
            results.push(StepResult {
                tool: step.tool.clone(),
                skipped: true,
                seconds: 0.0,
                log: Vec::new(),
            });
            continue;
        }
        eprintln!("  [run ] {}", step.tool);
        let t0 = Instant::now();
        let log = invoke(
            store,
            tool.as_ref(),
            step.params.clone(),
            &step.inputs,
            &step.outputs,
            engine.clone(),
        )
        .map_err(|e| format!("step '{}': {e}", step.tool))?;
        results.push(StepResult {
            tool: step.tool.clone(),
            skipped: false,
            seconds: t0.elapsed().as_secs_f64(),
            log,
        });
    }
    Ok(RunReport {
        workflow: wf.name.clone(),
        steps: results,
        seconds: t_all.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::artifact::formats;
    use crate::pipeline::tool::{Port, Tool, ToolCtx};

    struct Producer;
    impl Tool for Producer {
        fn name(&self) -> &str {
            "producer"
        }
        fn inputs(&self) -> Vec<Port> {
            vec![]
        }
        fn outputs(&self) -> Vec<Port> {
            vec![Port::new("out", formats::REPORT)]
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<(), String> {
            std::fs::write(ctx.output("out")?.join("x.json"), "{}").map_err(|e| e.to_string())
        }
    }

    struct Transformer;
    impl Tool for Transformer {
        fn name(&self) -> &str {
            "transformer"
        }
        fn inputs(&self) -> Vec<Port> {
            vec![Port::new("in", formats::REPORT)]
        }
        fn outputs(&self) -> Vec<Port> {
            vec![Port::new("out", formats::REPORT)]
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<(), String> {
            std::fs::copy(ctx.input("in")?.join("x.json"), ctx.output("out")?.join("x.json"))
                .map(|_| ())
                .map_err(|e| e.to_string())
        }
    }

    fn setup() -> (Registry, ArtifactStore) {
        let mut reg = Registry::new();
        reg.register(Arc::new(Producer));
        reg.register(Arc::new(Transformer));
        let d = std::env::temp_dir().join(format!(
            "bonseyes-wf-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        (reg, ArtifactStore::open(d).unwrap())
    }

    const WF: &str = r#"{
      "name": "t",
      "steps": [
        {"tool": "producer", "outputs": {"out": "a"}},
        {"tool": "transformer", "inputs": {"in": "a"}, "outputs": {"out": "b"}}
      ]
    }"#;

    #[test]
    fn parse_validate_run_skip() {
        let (reg, store) = setup();
        let wf = Workflow::parse(WF).unwrap();
        wf.validate(&reg, &store).unwrap();
        let rep = run(&wf, &reg, &store, None, false).unwrap();
        assert!(rep.steps.iter().all(|s| !s.skipped));
        assert!(store.exists("b"));
        // second run skips everything
        let rep2 = run(&wf, &reg, &store, None, false).unwrap();
        assert!(rep2.steps.iter().all(|s| s.skipped));
        // force re-runs
        let rep3 = run(&wf, &reg, &store, None, true).unwrap();
        assert!(rep3.steps.iter().all(|s| !s.skipped));
    }

    #[test]
    fn validation_catches_dangling_input() {
        let (reg, store) = setup();
        let wf = Workflow::parse(
            r#"{"name":"bad","steps":[{"tool":"transformer",
                "inputs":{"in":"nope"},"outputs":{"out":"b"}}]}"#,
        )
        .unwrap();
        let err = wf.validate(&reg, &store).unwrap_err();
        assert!(err.contains("neither produced"), "{err}");
    }

    #[test]
    fn validation_catches_unknown_tool() {
        let (reg, store) = setup();
        let wf = Workflow::parse(
            r#"{"name":"bad","steps":[{"tool":"ghost","outputs":{}}]}"#,
        )
        .unwrap();
        assert!(wf.validate(&reg, &store).unwrap_err().contains("unknown tool"));
    }

    #[test]
    fn report_serializes() {
        let (reg, store) = setup();
        let wf = Workflow::parse(WF).unwrap();
        let rep = run(&wf, &reg, &store, None, false).unwrap();
        let j = rep.to_json();
        assert_eq!(j.get("workflow").as_str(), Some("t"));
        assert_eq!(j.get("steps").as_arr().unwrap().len(), 2);
    }
}
