//! Proof of the record-once trace's central claim: a warmed `LneSession`
//! steady-state replay performs ZERO heap allocations — input staging,
//! epoch-counter resets, lock-free deque dispatch, GEMM execution,
//! condvar parking and metrics recording all reuse preallocated storage.
//!
//! This lives in its own test binary because the counting allocator must
//! be the process-wide `#[global_allocator]`, and a SINGLE `#[test]`
//! keeps concurrently running tests from polluting the armed window
//! (the counter observes every thread, deliberately — that is how pool
//! workers are covered).

use bonseyes::lne::platform::Platform;
use bonseyes::lne::plugin::{Assignment, ConvImpl};
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::lne::{ArenaPool, Graph, LayerKind, Padding, PoolKind, Prepared};
use bonseyes::models;
use bonseyes::serving::{InferenceSession, LneSession, ServingMetrics, WorkerPool};
use bonseyes::tensor::Tensor;
use bonseyes::testing::alloc_counter::{arm, disarm, CountingAlloc};
use bonseyes::util::rng::Rng;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Warm a session at `bucket` (record the trace, size the arena, seed
/// the metrics entries, grow the worker pool's queue to steady state),
/// then prove repeated staged replays allocate nothing.
fn prove_zero_alloc(name: &str, s: &mut LneSession, bucket: usize, x: &[f32]) {
    s.run_batch(bucket, &[x]).unwrap();
    for _ in 0..3 {
        s.replay_staged(bucket).unwrap();
    }
    arm();
    for _ in 0..8 {
        s.replay_staged(bucket).unwrap();
    }
    let (allocs, bytes) = disarm();
    assert_eq!(
        allocs, 0,
        "{name}: steady-state trace replay allocated {allocs} times ({bytes} bytes)"
    );
}

#[test]
fn warmed_steady_state_replays_allocate_nothing() {
    // One router-style substrate shared by every session, as in serving:
    // pooled arenas, one worker pool, one metrics sink.
    let pool = ArenaPool::new();
    let workers = Arc::new(WorkerPool::new(2));
    let metrics = Arc::new(ServingMetrics::default());
    let mut rng = Rng::new(77);

    // (1) f32 branchy model: wave width >= 2, so the replay actually runs
    // the parallel trace machinery (deques, parking, epoch resets)
    let g = models::inceptionette::inceptionette();
    let w = models::random_weights(&g, 9);
    let p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let a = f32_baseline(&p);
    let mut f32_s = LneSession::new(p, a, &[2], &[], &pool, Arc::clone(&workers))
        .unwrap()
        .with_metrics(Arc::clone(&metrics));
    let f32_x = Tensor::randn(&[3, 16, 16], 1.0, &mut rng).data;
    prove_zero_alloc("f32-branchy", &mut f32_s, 2, &f32_x);

    // (2) int8-resident conv chain: quantized lanes, boundary
    // conversions, per-image scale bookkeeping — all arena-backed
    let mut g = Graph::new("i8steady", (2, 8, 8));
    g.push("c1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("c2", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("c3", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: false }, 3);
    let w = models::random_weights(&g, 13);
    let p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let mut a = Assignment::default_for(&p.graph);
    for c in a.choices.iter_mut() {
        *c = Some(ConvImpl::Int8Gemm);
    }
    let mut i8_s = LneSession::new(p, a, &[2], &[], &pool, Arc::clone(&workers))
        .unwrap()
        .with_metrics(Arc::clone(&metrics));
    let i8_x = Tensor::randn(&[2, 8, 8], 1.0, &mut rng).data;
    prove_zero_alloc("int8-resident", &mut i8_s, 2, &i8_x);

    // (3) cascade-style staged pair: a gate and a downstream model in a
    // different input space, sharing the arena pool and worker pool the
    // way `serving::cascade` stages do
    let mut g = Graph::new("gate", (2, 6, 6));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 3);
    g.push("prob", LayerKind::Softmax, 0);
    let w = models::random_weights(&g, 5);
    let p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let a = f32_baseline(&p);
    let mut gate_s = LneSession::new(p, a, &[1, 4], &[], &pool, Arc::clone(&workers))
        .unwrap()
        .with_metrics(Arc::clone(&metrics));
    let gate_x = Tensor::randn(&[2, 6, 6], 1.0, &mut rng).data;

    let mut g = Graph::new("heavy", (3, 8, 8));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 8);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 5);
    let w = models::random_weights(&g, 9);
    let p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let a = f32_baseline(&p);
    let mut heavy_s = LneSession::new(p, a, &[1, 4], &[], &pool, Arc::clone(&workers))
        .unwrap()
        .with_metrics(Arc::clone(&metrics));
    let heavy_x = Tensor::randn(&[3, 8, 8], 1.0, &mut rng).data;

    prove_zero_alloc("cascade-gate", &mut gate_s, 4, &gate_x);
    prove_zero_alloc("cascade-heavy", &mut heavy_s, 4, &heavy_x);

    // the metrics sink saw every replay: 4 sessions × (1 run_batch + 11
    // staged replays), all but the four recording replays trace hits
    let snap = metrics.snapshot();
    assert_eq!(snap.get("replays").as_i64(), Some(48));
    assert_eq!(snap.get("trace_misses").as_i64(), Some(4));
    assert_eq!(snap.get("trace_hits").as_i64(), Some(44));
}
