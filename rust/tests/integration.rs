//! End-to-end integration over the real AOT artifacts: pipeline workflow
//! (synthetic speech-commands import -> partition -> MFCC via the pallas
//! kernel through PJRT -> train-step execution -> accuracy benchmark ->
//! Q/S compression), exercising every stage of the paper's §3-§5 pipeline.
//!
//! Skipped (with a message) when `make artifacts` hasn't been run.

use bonseyes::ingestion::bta::{Bta, Dataset};
use bonseyes::ingestion::tools::DATA_FILE;
use bonseyes::pipeline::artifact::ArtifactStore;
use bonseyes::pipeline::tool::Registry;
use bonseyes::pipeline::workflow::{run, Workflow};
use bonseyes::runtime::{EngineHandle, OwnedInput};
use bonseyes::training::tools::load_model;
use bonseyes::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn registry() -> Registry {
    let mut reg = Registry::new();
    reg.register(Arc::new(bonseyes::ingestion::SpeechCommandsImport));
    reg.register(Arc::new(bonseyes::ingestion::PartitionTool));
    reg.register(Arc::new(bonseyes::ingestion::MfccTool));
    reg.register(Arc::new(bonseyes::training::TrainKws));
    reg.register(Arc::new(bonseyes::training::BenchmarkKws));
    reg.register(Arc::new(bonseyes::training::QuantizeModel));
    reg.register(Arc::new(bonseyes::training::SparsifyModel));
    reg
}

#[test]
fn mfcc_graph_runs_and_matches_expected_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = EngineHandle::spawn(&dir).unwrap();
    let m = &engine.manifest;
    let audio = vec![0.1f32; m.samples];
    let out = engine
        .run("mfcc_b1", vec![OwnedInput::new(audio, &[1, m.samples])])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m.mel_bands * m.frames);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn infer_graph_runs_from_init_state() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = EngineHandle::spawn(&dir).unwrap();
    let m = engine.manifest.clone();
    let arch = m.arch("ds_kws9").expect("ds_kws9 in manifest");
    let params = engine.read_blob(&arch.init_file).unwrap();
    let stats = engine.read_blob(&arch.init_stats_file).unwrap();
    let x = vec![0.0f32; m.mel_bands * m.frames];
    let out = engine
        .run(
            "ds_kws9_infer_b1",
            vec![
                OwnedInput::new(params, &[arch.n_params]),
                OwnedInput::new(stats, &[arch.n_stats]),
                OwnedInput::new(x, &[1, m.mel_bands, m.frames]),
            ],
        )
        .unwrap();
    assert_eq!(out[0].len(), m.num_classes);
}

#[test]
fn full_pipeline_workflow_learns_and_compresses() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = EngineHandle::spawn(&dir).unwrap();
    let store_dir = std::env::temp_dir().join(format!("bonseyes-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = ArtifactStore::open(&store_dir).unwrap();
    let reg = registry();
    let wf = Workflow::parse(
        r#"{
      "name": "kws-e2e-test",
      "steps": [
        {"tool": "speech-commands-import", "params": {"per_class": 16, "seed": 5},
         "outputs": {"data": "raw"}},
        {"tool": "partition", "params": {"val_frac": 0.15, "test_frac": 0.15},
         "inputs": {"data": "raw"},
         "outputs": {"train": "raw-train", "val": "raw-val", "test": "raw-test"}},
        {"tool": "mfcc-features", "inputs": {"data": "raw-train"}, "outputs": {"features": "mfcc-train"}},
        {"tool": "mfcc-features", "inputs": {"data": "raw-val"}, "outputs": {"features": "mfcc-val"}},
        {"tool": "mfcc-features", "inputs": {"data": "raw-test"}, "outputs": {"features": "mfcc-test"}},
        {"tool": "train-kws", "params": {"arch": "ds_kws9", "iterations": 40, "eval_every": 40},
         "inputs": {"train": "mfcc-train", "val": "mfcc-val"},
         "outputs": {"model": "model"}},
        {"tool": "benchmark-kws", "inputs": {"model": "model", "test": "mfcc-test"},
         "outputs": {"report": "report"}},
        {"tool": "quantize-model", "inputs": {"model": "model"}, "outputs": {"model": "model-q"}},
        {"tool": "sparsify-model", "params": {"fraction": 0.3},
         "inputs": {"model": "model-q"}, "outputs": {"model": "model-qs"}},
        {"tool": "benchmark-kws", "inputs": {"model": "model-qs", "test": "mfcc-test"},
         "outputs": {"report": "report-qs"}}
      ]
    }"#,
    )
    .unwrap();
    let rep = run(&wf, &reg, &store, Some(engine.clone()), false).unwrap();
    assert_eq!(rep.steps.len(), 10);

    // the training loss must decrease substantially over 40 steps
    let model = load_model(&store.dir("model")).unwrap();
    let hist = model.meta.get("history").as_arr().unwrap().to_vec();
    let first: f64 = hist[0].at(1).as_f64().unwrap();
    let last: f64 = hist[hist.len() - 1].at(1).as_f64().unwrap();
    assert!(last < first * 0.8, "loss did not fall: {first} -> {last}");

    // reports exist and are parseable; quantized+sparse model still predicts
    let rep_json = Json::parse(
        &std::fs::read_to_string(store.dir("report").join("report.json")).unwrap(),
    )
    .unwrap();
    let acc = rep_json.get("accuracy").as_f64().unwrap();
    assert!((0.0..=1.0).contains(&acc));
    let rep_qs = Json::parse(
        &std::fs::read_to_string(store.dir("report-qs").join("report.json")).unwrap(),
    )
    .unwrap();
    assert!(rep_qs.get("sparsity").as_f64().unwrap() > 0.2);
    assert!(rep_qs.get("size_kb").as_f64().unwrap()
            < rep_json.get("size_kb").as_f64().unwrap());

    // MFCC artifacts have the documented shape
    let bta = Bta::load(&store.dir("mfcc-test").join(DATA_FILE)).unwrap();
    let ds = Dataset::from_bta(&bta, "mfcc").unwrap();
    assert_eq!(ds.row(), engine.manifest.mel_bands * engine.manifest.frames);
}

/// Plan/arena serving path end to end — requires no AOT artifacts: build
/// paper KWS architectures as LNE models, register them behind the
/// `ModelRouter` as `InferenceSession` backends, and serve requests
/// (sync + async) with cross-model arena sharing and planned
/// (== observed) peak memory.
#[test]
fn lne_planned_serving_runs_without_artifacts() {
    use bonseyes::lne::planner::Arena;
    use bonseyes::lne::platform::Platform;
    use bonseyes::nas::evaluator::lne_prepared;
    use bonseyes::nas::space::paper_arch;
    use bonseyes::serving::{BatcherConfig, ModelRouter, Ticket};
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;

    let arch = paper_arch("kws9").unwrap();
    let (p, a) = lne_prepared(&arch, 3, Platform::pi4()).unwrap();
    let (c, h, wd) = p.graph.input;

    // planned == observed peak on a direct replay
    let plan = p.plan(&a, 1).unwrap();
    let mut arena = Arena::for_plan(&plan);
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[1, c, h, wd], 1.0, &mut rng);
    let r = plan.replay(&x, &mut arena);
    assert_eq!(r.peak_bytes, plan.arena_bytes());
    assert!(r.output.data.iter().all(|v| v.is_finite()));

    // the same prepared model (twice) behind the production router:
    // identical high-water profiles share pooled arenas
    let mut router = ModelRouter::new();
    let cfg = BatcherConfig { max_wait_ms: 1.0, ..Default::default() };
    let (p2, a2) = lne_prepared(&arch, 3, Platform::pi4()).unwrap();
    router.register_lne("kws9", p, a, &[1, 4], &[], cfg.clone()).unwrap();
    router.register_lne("kws9_replica", p2, a2, &[1, 4], &[], cfg).unwrap();
    assert_eq!(router.models().len(), 2);
    // identical profiles shared + the batch-1 profile borrowing the
    // batch-4 arena (compatible-profile lending) -> one arena, not 2x2
    assert_eq!(router.arena_pool.arena_count(), 1, "1 lent arena, not 2x2");

    // async submissions round-trip through the coalescing batcher
    let tickets: Vec<Ticket> = (0..5)
        .map(|_| {
            let s = Tensor::randn(&[c, h, wd], 1.0, &mut rng).data;
            router.infer_async(None, s).unwrap()
        })
        .collect();
    for t in tickets {
        let pred = t.wait().unwrap();
        assert_eq!(pred.scores.len(), 12); // NUM_CLASSES
        assert!(pred.scores.iter().all(|v| v.is_finite()));
        assert!(pred.class_id < 12);
    }
    // and the replica answers identically through the same API
    let s = Tensor::randn(&[c, h, wd], 1.0, &mut rng).data;
    let m1 = router.infer(Some("kws9"), s.clone()).unwrap();
    let m2 = router.infer(Some("kws9_replica"), s).unwrap();
    assert_eq!(m1.class_id, m2.class_id);
}

/// Cascade serving end to end — no artifacts: a two-stage early-exit
/// pipeline (3-class softmax gate -> 5-class heavier model in a different
/// input space) registered behind the `ModelRouter` as ONE model and
/// served through the dynamic batcher.
///
/// Proves (a) early-exited items return the GATE stage's result (its
/// 3-score prediction) and the downstream stage never executes for them —
/// asserted via the per-stage items-in/items-out/early-exit metrics — and
/// (b) the cascade's outputs are bit-exact with manually running the same
/// sessions in sequence, at worker-pool sizes 1 / 2 / 4.
#[test]
fn cascade_early_exit_serving_is_bit_exact_with_manual_staging() {
    use bonseyes::lne::platform::Platform;
    use bonseyes::lne::quant_explore::f32_baseline;
    use bonseyes::lne::{ArenaPool, Graph, LayerKind, Padding, PoolKind, Prepared};
    use bonseyes::models;
    use bonseyes::serving::cascade::{pick_bucket, Cascade, Gate, Stage, Transform};
    use bonseyes::serving::{
        BatcherConfig, InferenceSession, LneSession, ModelRouter, WorkerPool,
    };
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;

    // gate: tiny 3-class model ending in Softmax, so its scores are
    // probabilities and confidence thresholds calibrate directly
    let mut g = Graph::new("gate", (2, 6, 6));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 3);
    g.push("prob", LayerKind::Softmax, 0);
    let w = models::random_weights(&g, 5);
    let gate_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let gate_a = f32_baseline(&gate_p);

    // downstream: a 5-class model in its OWN input space (3x8x8), so a
    // prediction's score length tells us which stage answered (3 vs 5)
    let mut g = Graph::new("heavy", (3, 8, 8));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 8);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 5);
    let w = models::random_weights(&g, 9);
    let heavy_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let heavy_a = f32_baseline(&heavy_p);
    let tr = Transform { resize: Some(((2, 6, 6), (3, 8, 8))), renormalize: true };

    let mut rng = Rng::new(33);
    let samples: Vec<Vec<f32>> =
        (0..6).map(|_| Tensor::randn(&[2, 6, 6], 1.0, &mut rng).data).collect();

    // calibrate a threshold that splits the first four samples 2/2 by the
    // gate's top-1 confidence: items BELOW it continue, the rest exit early
    let top1: Vec<f32> = samples
        .iter()
        .map(|s| {
            let x = Tensor::from_vec(&[1, 2, 6, 6], s.clone());
            gate_p.run(&x, &gate_a).output.data.iter().cloned().fold(f32::MIN, f32::max)
        })
        .collect();
    let mut sorted: Vec<f32> = top1[..4].to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let thresh = sorted[2];

    // (a) through the router: register the cascade as one model, serve 6
    // requests through the batcher, and read the per-stage accounting
    let mut router = ModelRouter::with_threads(2);
    let gate = Stage::lne(
        "gate",
        Arc::clone(&gate_p),
        gate_a.clone(),
        &[1, 4],
        &[],
        Gate::ConfidenceBelow(thresh),
        Transform::identity(),
        &router.arena_pool,
        Arc::clone(&router.worker_pool),
    )
    .unwrap();
    let heavy = Stage::lne(
        "heavy",
        Arc::clone(&heavy_p),
        heavy_a.clone(),
        &[1, 4],
        &[],
        Gate::ConfidenceBelow(0.0),
        tr.clone(),
        &router.arena_pool,
        Arc::clone(&router.worker_pool),
    )
    .unwrap();
    let cascade = Cascade::new("casc").push(gate).unwrap().push(heavy).unwrap();
    router
        .register_cascade(cascade, BatcherConfig { max_wait_ms: 1.0, ..Default::default() })
        .unwrap();
    assert_eq!(router.input_len(Some("casc")).unwrap(), 2 * 6 * 6);
    assert_eq!(router.num_classes(Some("casc")).unwrap(), 5);

    let mut exits = 0usize;
    let mut survivors = 0usize;
    for s in &samples {
        let p = router.infer(Some("casc"), s.clone()).unwrap();
        match p.scores.len() {
            3 => exits += 1,      // answered by the gate: its own class set
            5 => survivors += 1,  // answered downstream
            n => panic!("prediction from neither stage ({n} scores)"),
        }
        assert!(p.scores.iter().all(|v| v.is_finite()));
    }
    assert!(exits >= 1 && survivors >= 1, "threshold must split: {exits}/{survivors}");

    // items the gate exited never reached the heavy stage
    let snap = router.metrics.snapshot();
    let stages = snap.get("cascade_stages");
    let g_stats = stages.get("casc/0:gate");
    assert_eq!(g_stats.get("items_in").as_i64(), Some(6));
    assert_eq!(g_stats.get("items_out").as_i64(), Some(survivors as i64));
    assert_eq!(g_stats.get("early_exits").as_i64(), Some(exits as i64));
    let h_stats = stages.get("casc/1:heavy");
    assert_eq!(h_stats.get("items_in").as_i64(), Some(survivors as i64));
    assert_eq!(h_stats.get("items_out").as_i64(), Some(0), "last stage forwards nothing");
    assert_eq!(h_stats.get("early_exits").as_i64(), Some(0));

    // (b) fixed batch composition: the cascade must be bit-exact with
    // manually staging the SAME sessions — gate over the full batch, then
    // the survivors re-coalesced into the smallest covering bucket — and
    // bit-exact across worker-pool sizes
    let refs4: Vec<&[f32]> = samples[..4].iter().map(|v| v.as_slice()).collect();
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for threads in [1usize, 2, 4] {
        let pool = ArenaPool::new();
        let w = Arc::new(WorkerPool::new(threads));
        let gate = Stage::lne(
            "gate",
            Arc::clone(&gate_p),
            gate_a.clone(),
            &[1, 4],
            &[],
            Gate::ConfidenceBelow(thresh),
            Transform::identity(),
            &pool,
            Arc::clone(&w),
        )
        .unwrap();
        let heavy = Stage::lne(
            "heavy",
            Arc::clone(&heavy_p),
            heavy_a.clone(),
            &[1, 4],
            &[],
            Gate::ConfidenceBelow(0.0),
            tr.clone(),
            &pool,
            Arc::clone(&w),
        )
        .unwrap();
        let mut cascade = Cascade::new("direct").push(gate).unwrap().push(heavy).unwrap();
        let got: Vec<Vec<f32>> = cascade
            .run_batch(4, &refs4)
            .unwrap()
            .into_iter()
            .map(|p| p.scores)
            .collect();

        // manual staging of the same prepared models on the same pool
        let mut gate_s = LneSession::new(
            Arc::clone(&gate_p),
            gate_a.clone(),
            &[1, 4],
            &[],
            &pool,
            Arc::clone(&w),
        )
        .unwrap();
        let mut heavy_s =
            LneSession::new(Arc::clone(&heavy_p), heavy_a.clone(), &[1, 4], &[], &pool, w)
                .unwrap();
        let gate_preds = gate_s.run_batch(4, &refs4).unwrap();
        let live: Vec<usize> = (0..4)
            .filter(|&i| Gate::ConfidenceBelow(thresh).passes(&gate_preds[i].scores))
            .collect();
        assert!(!live.is_empty() && live.len() < 4, "need both populations: {live:?}");
        let payloads: Vec<Vec<f32>> =
            live.iter().map(|&i| tr.apply(refs4[i]).unwrap()).collect();
        let chunk: Vec<&[f32]> = payloads.iter().map(|v| v.as_slice()).collect();
        let b = pick_bucket(heavy_s.buckets(), live.len());
        let heavy_preds = heavy_s.run_batch(b, &chunk).unwrap();
        let mut want: Vec<Vec<f32>> = gate_preds.into_iter().map(|p| p.scores).collect();
        for (j, &i) in live.iter().enumerate() {
            want[i] = heavy_preds[j].scores.clone();
        }
        assert_eq!(got, want, "threads={threads}: cascade != manual staging");
        if let Some(r) = &reference {
            assert_eq!(&got, r, "threads={threads} diverged from threads=1");
        } else {
            reference = Some(got);
        }
    }
}

/// Hot backend swap under concurrent load (DESIGN.md §14): tickets in
/// flight on the OLD replica set when `replace_session` runs must all
/// resolve — the old drains own the queue receiver and finish the backlog
/// before exiting — while the name immediately serves from the new
/// backend (observable through its distinct class names). Waits are
/// bounded (`Ticket::wait_timeout`), so a dropped backlog fails the test
/// instead of hanging it.
#[test]
fn replace_session_under_load_resolves_in_flight_tickets() {
    use bonseyes::lne::platform::Platform;
    use bonseyes::nas::evaluator::lne_prepared;
    use bonseyes::nas::space::paper_arch;
    use bonseyes::serving::{BatcherConfig, LneSession, ModelRouter, Ticket};
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;
    use std::time::Duration;

    let arch = paper_arch("kws9").unwrap();
    let (p, a) = lne_prepared(&arch, 3, Platform::pi4()).unwrap();
    let (c, h, w) = p.graph.input;
    let mut router = ModelRouter::with_threads(2);
    // a long coalescing window keeps the submissions queued on the old
    // batcher while the swap happens underneath them
    router
        .register_lne(
            "kws9",
            Arc::clone(&p),
            a.clone(),
            &[1, 4],
            &[],
            BatcherConfig { max_wait_ms: 200.0, ..Default::default() },
        )
        .unwrap();
    let mut rng = Rng::new(9);
    let tickets: Vec<Ticket> = (0..6)
        .map(|_| {
            let s = Tensor::randn(&[c, h, w], 1.0, &mut rng).data;
            router.infer_async(None, s).unwrap()
        })
        .collect();

    // swap the backend while those are in flight
    let swap_classes: Vec<String> = (0..12).map(|i| format!("swap_{i}")).collect();
    let session = LneSession::new(
        Arc::clone(&p),
        a.clone(),
        &[1, 4],
        &swap_classes,
        &router.arena_pool,
        Arc::clone(&router.worker_pool),
    )
    .unwrap();
    router
        .replace_session(
            "kws9",
            Box::new(session),
            BatcherConfig { max_wait_ms: 1.0, ..Default::default() },
        )
        .unwrap();

    // every in-flight ticket resolves from the old set's drained backlog
    for t in &tickets {
        let pred = t
            .wait_timeout(Duration::from_secs(5))
            .expect("in-flight ticket must resolve across replace_session");
        assert_eq!(pred.scores.len(), 12);
        assert!(!pred.class.starts_with("swap_"), "old backlog served by old backend");
    }
    // and the name now serves from the new backend
    let s = Tensor::randn(&[c, h, w], 1.0, &mut rng).data;
    let pred = router.infer(Some("kws9"), s).unwrap();
    assert!(pred.class.starts_with("swap_"), "swapped backend must answer: {}", pred.class);
}

/// Load shedding at the router level is deterministic and non-blocking:
/// with a tiny bounded admission queue, a burst of async submissions
/// never blocks the submitting thread and every request either resolves
/// OK or fails fast with the typed `QueueFull` — nothing is silently
/// dropped, and the metrics ledger matches the caller's own counts.
#[test]
fn bounded_admission_sheds_bursts_without_blocking() {
    use bonseyes::lne::platform::Platform;
    use bonseyes::nas::evaluator::lne_prepared;
    use bonseyes::nas::space::paper_arch;
    use bonseyes::serving::{BatcherConfig, ModelRouter, SubmitError};
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;
    use std::time::Instant;

    let arch = paper_arch("kws9").unwrap();
    let (p, a) = lne_prepared(&arch, 3, Platform::pi4()).unwrap();
    let (c, h, w) = p.graph.input;
    let mut router = ModelRouter::with_threads(2);
    router
        .register_lne(
            "kws9",
            p,
            a,
            &[1],
            &[],
            BatcherConfig {
                max_wait_ms: 0.0,
                max_batch: 1,
                queue_cap: Some(2),
                ..Default::default()
            },
        )
        .unwrap();

    let mut rng = Rng::new(21);
    let burst = 64usize;
    let t0 = Instant::now();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..burst {
        let s = Tensor::randn(&[c, h, w], 1.0, &mut rng).data;
        match router.infer_async(None, s) {
            Ok(t) => admitted.push(t),
            Err(SubmitError::QueueFull { cap }) => {
                assert_eq!(cap, 2);
                shed += 1;
            }
            Err(e) => panic!("burst must shed with QueueFull, got {e}"),
        }
    }
    // admission never blocked on inference (the burst is orders of
    // magnitude faster to submit than to serve)
    assert!(t0.elapsed().as_secs_f64() < 5.0, "submission loop blocked");
    assert!(shed >= 1, "cap-2 queue must shed a 64-burst");
    assert_eq!(admitted.len() as u64 + shed, burst as u64, "no request unaccounted");

    // every admitted ticket resolves OK — shedding never eats admitted work
    for t in admitted.iter() {
        let pred = t
            .wait_timeout(std::time::Duration::from_secs(10))
            .expect("admitted ticket must resolve");
        assert_eq!(pred.scores.len(), 12);
    }
    let snap = router.metrics.snapshot();
    assert_eq!(snap.get("shed_total").as_i64(), Some(shed as i64));
    assert_eq!(snap.get("requests").as_i64(), Some(admitted.len() as i64));
    assert_eq!(snap.get("evicted_total").as_i64(), Some(0));
}

/// Wavefront-parallel serving end to end: a branchy model (inceptionette)
/// served through routers whose shared worker pools have 1 / 2 / 4
/// threads must produce identical predictions — the planner's
/// disjointness invariant makes parallel replay bit-exact — and the
/// metrics must report the plan's wavefront shape.
#[test]
fn wavefront_parallel_serving_is_bit_exact_across_thread_counts() {
    use bonseyes::lne::engine::Prepared;
    use bonseyes::lne::platform::Platform;
    use bonseyes::lne::quant_explore::f32_baseline;
    use bonseyes::models;
    use bonseyes::serving::{BatcherConfig, ModelRouter};
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;

    let mut rng = Rng::new(77);
    let samples: Vec<Vec<f32>> = (0..3)
        .map(|_| Tensor::randn(&[3, 16, 16], 1.0, &mut rng).data)
        .collect();
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for threads in [1usize, 2, 4] {
        let g = models::inceptionette::inceptionette();
        let w = models::random_weights(&g, 5);
        let p = std::sync::Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
        let a = f32_baseline(&p);
        let mut router = ModelRouter::with_threads(threads);
        assert_eq!(router.worker_pool.threads(), threads);
        router
            .register_lne(
                "incep",
                p,
                a,
                &[1, 4],
                &[],
                BatcherConfig { max_wait_ms: 1.0, ..Default::default() },
            )
            .unwrap();
        let scores: Vec<Vec<f32>> = samples
            .iter()
            .map(|s| router.infer(None, s.clone()).unwrap().scores)
            .collect();
        if let Some(want) = reference.as_ref() {
            for (got_row, want_row) in scores.iter().zip(want.iter()) {
                for (got, want) in got_row.iter().zip(want_row.iter()) {
                    assert_eq!(got, want, "threads={threads} diverged");
                }
            }
        } else {
            reference = Some(scores);
        }
        let snap = router.metrics.snapshot();
        assert_eq!(snap.get("replays").as_i64(), Some(3));
        assert!(snap.get("wave_width_max").as_f64().unwrap() >= 4.0, "inception towers");
    }
}

/// The SIMD dispatch seam can never fork serving results: one full
/// `LneSession` replay pipeline — inceptionette served f32 and
/// int8-resident through a `ModelRouter` — must produce bit-identical
/// predictions with the scalar backend pinned (the in-process equivalent
/// of `BONSEYES_NO_SIMD=1`, which latches the same flag from the
/// environment at first use) and with the detected backend, at worker
/// pools of 1 / 2 / 4 threads. On hosts without AVX2/NEON both modes
/// resolve to scalar and the comparison is trivially green.
#[test]
fn simd_and_scalar_serving_predictions_are_bit_identical() {
    use bonseyes::lne::engine::Prepared;
    use bonseyes::lne::platform::Platform;
    use bonseyes::lne::plugin::{ConvImpl, DesignSpace};
    use bonseyes::lne::primitives::simd::KernelBackend;
    use bonseyes::lne::quant_explore::f32_baseline;
    use bonseyes::models;
    use bonseyes::serving::{BatcherConfig, ModelRouter};
    use bonseyes::tensor::Tensor;
    use bonseyes::util::rng::Rng;

    let mut rng = Rng::new(41);
    let samples: Vec<Vec<f32>> = (0..3)
        .map(|_| Tensor::randn(&[3, 16, 16], 1.0, &mut rng).data)
        .collect();

    // Serve every sample through a fresh router (f32 + int8-resident
    // registrations, Prepared rebuilt under the mode's backend so the
    // autotune key matches what serving would really do) and collect the
    // concatenated predictions.
    let serve = |threads: usize| -> Vec<Vec<f32>> {
        let mut router = ModelRouter::with_threads(threads);
        let cfg = || BatcherConfig { max_wait_ms: 1.0, ..Default::default() };
        let g = models::inceptionette::inceptionette();
        let w = models::random_weights(&g, 5);
        let p = std::sync::Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
        let a = f32_baseline(&p);
        router.register_lne("incep-f32", p, a, &[1, 4], &[], cfg()).unwrap();

        let g = models::inceptionette::inceptionette();
        let w = models::random_weights(&g, 5);
        let space = DesignSpace::build(&g, &Platform::pi4());
        let a = space.uniform(&g, ConvImpl::Int8Gemm);
        let p = std::sync::Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
        router.register_lne("incep-i8", p, a, &[1, 4], &[], cfg()).unwrap();

        let mut out = Vec::new();
        for model in ["incep-f32", "incep-i8"] {
            for s in &samples {
                out.push(router.infer(Some(model), s.clone()).unwrap().scores);
            }
        }
        out
    };

    let mut by_mode: Vec<Vec<Vec<Vec<f32>>>> = Vec::new();
    for scalar_pinned in [true, false] {
        let prev = KernelBackend::force_scalar(scalar_pinned);
        let per_thread: Vec<Vec<Vec<f32>>> = [1usize, 2, 4].iter().map(|&t| serve(t)).collect();
        KernelBackend::force_scalar(prev);
        // threads {1,2,4} agree within the mode (the existing invariant)
        for t in &per_thread[1..] {
            assert_eq!(t, &per_thread[0], "thread counts diverged within one backend mode");
        }
        by_mode.push(per_thread);
    }
    // and the two modes agree bit for bit across the seam
    for (scalar_preds, simd_preds) in by_mode[0][0].iter().zip(by_mode[1][0].iter()) {
        assert_eq!(scalar_preds.len(), simd_preds.len());
        for (a, b) in scalar_preds.iter().zip(simd_preds.iter()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "scalar vs {:?} backend forked a served prediction",
                KernelBackend::detected()
            );
        }
    }
}
