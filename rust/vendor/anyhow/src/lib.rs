//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io registry, so this path crate provides
//! the small surface the repo actually uses: `Error`, `Result`, the
//! `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait on `Result` and `Option`. Errors are flattened to their display
//! string; context is prepended `"context: cause"` like anyhow renders its
//! chain with `{:#}`.

use std::fmt;

/// A type-erased error: the rendered message of whatever was raised.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (what `.context()` does on results).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // main() exits print Debug; keep it human-readable.
        f.write_str(&self.msg)
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// std::error::Error, which is what makes this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message (format string) or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn macros_format() {
        let name = "x";
        assert_eq!(anyhow!("missing {name}").to_string(), "missing x");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
        assert_eq!(anyhow!("{} {}", 1, 2).to_string(), "1 2");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("absent").is_err());
        assert_eq!(Some(3u32).with_context(|| "absent").unwrap(), 3);
    }
}
