//! Cascade serving bench: end-to-end latency of a two-stage early-exit
//! pipeline (cheap gate → heavy branchy model) at exit rates 0% / ~50% /
//! 100%. The point being measured: a batch entering the downstream stage
//! re-coalesces ONLY the gate's survivors into the smallest covering
//! bucket, so the heavy stage's work — and the pipeline's latency —
//! shrinks as the exit rate rises.
#[path = "common.rs"]
mod common;

use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::lne::{ArenaPool, Graph, LayerKind, Padding, PoolKind, Prepared};
use bonseyes::models;
use bonseyes::serving::cascade::{Cascade, Gate, Stage, Transform};
use bonseyes::serving::{InferenceSession, ServingMetrics, WorkerPool};
use bonseyes::tensor::Tensor;
use bonseyes::util::rng::Rng;
use bonseyes::util::stats::median;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    common::banner("cascade", "two-stage early-exit pipeline: latency vs exit rate");
    let reps = common::reps();
    let n = common::scaled(32, 8);

    // cheap gate: a tiny binary "wake" classifier ending in softmax, so
    // its scores are probabilities and thresholds calibrate directly
    let mut g = Graph::new("gate", (1, 12, 12));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 2);
    g.push("prob", LayerKind::Softmax, 0);
    let w = models::random_weights(&g, 5);
    let gate_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let gate_a = f32_baseline(&gate_p);

    // heavy downstream: the branchy inceptionette in its own input space
    let g = models::inceptionette::inceptionette();
    let w = models::random_weights(&g, 7);
    let cmd_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let cmd_a = f32_baseline(&cmd_p);

    let mut rng = Rng::new(3);
    let samples: Vec<Vec<f32>> =
        (0..n).map(|_| Tensor::randn(&[1, 12, 12], 1.0, &mut rng).data).collect();
    let refs: Vec<&[f32]> = samples.iter().map(|v| v.as_slice()).collect();

    // calibrate the ~50% threshold from the gate's top-1 confidences
    let top1: Vec<f32> = samples
        .iter()
        .map(|s| {
            let x = Tensor::from_vec(&[1, 1, 12, 12], s.clone());
            let out = gate_p.run(&x, &gate_a);
            out.output.data.iter().cloned().fold(f32::MIN, f32::max)
        })
        .collect();
    let mut sorted = top1.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t50 = sorted[n / 2];

    println!("{n} items/batch, gate 1x12x12 -> heavy 3x16x16, {reps} reps\n");
    println!("  exit-rate   survivors   end-to-end (median)");
    for (label, thresh) in [("0%", 2.0f32), ("~50%", t50), ("100%", 0.0)] {
        let pool = ArenaPool::new();
        let workers = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(ServingMetrics::default());
        let gate = Stage::lne(
            "gate",
            Arc::clone(&gate_p),
            gate_a.clone(),
            &[n],
            &[],
            Gate::ConfidenceBelow(thresh),
            Transform::identity(),
            &pool,
            Arc::clone(&workers),
        )
        .unwrap();
        let heavy = Stage::lne(
            "heavy",
            Arc::clone(&cmd_p),
            cmd_a.clone(),
            &[1, 8, n],
            &[],
            Gate::ConfidenceBelow(0.0),
            Transform { resize: Some(((1, 12, 12), (3, 16, 16))), renormalize: true },
            &pool,
            workers,
        )
        .unwrap();
        let mut cascade = Cascade::new("bench")
            .push(gate)
            .unwrap()
            .push(heavy)
            .unwrap()
            .with_metrics(Arc::clone(&metrics));
        let _ = cascade.run_batch(n, &refs).unwrap(); // warm-up
        let ms = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    let _ = cascade.run_batch(n, &refs).unwrap();
                    t0.elapsed().as_secs_f64() * 1e3
                })
                .collect(),
        );
        let survivors = top1.iter().filter(|&&v| v < thresh).count();
        println!("  {label:>9}   {survivors:9}   {ms:10.2} ms");
    }
    println!("\n(the heavy stage re-coalesces only gate survivors into its smallest");
    println!(" covering bucket, so downstream work shrinks with the exit rate)");
}
