//! Wavefront scheduling bench: sequential `ExecPlan::replay` vs
//! wavefront-parallel `replay_on` over a shared worker pool, on branchy
//! models (inception towers, residual legs). Demonstrates the wall-clock
//! speedup parallel branch execution buys on multi-branch wavefronts;
//! chain-shaped models (kws family) show ~1.0x by construction, so only
//! branchy zoo members appear here.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::planner::Arena;
use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::models;
use bonseyes::util::stats::median;
use bonseyes::util::threadpool::ThreadPool;

fn main() {
    common::banner(
        "wavefront",
        "parallel branch execution on the shared worker pool",
    );
    let reps = common::reps().max(3);
    println!(
        "{:<14} {:>5} {:>9} {:>12} {:>16} {:>16}",
        "model", "waves", "max-width", "seq ms", "2 threads", "4 threads"
    );
    for name in ["inceptionette", "googlenet", "squeezenet"] {
        let (g, w) = models::by_name(name, 42).expect("zoo model");
        let p = Prepared::new(g, w, Platform::pi4()).expect("prepared");
        let a = f32_baseline(&p);
        let plan = p.plan(&a, 1).expect("plan");
        let mut arena = Arena::for_plan(&plan);
        let x = common::image_input(&p.graph, 7);
        let _ = plan.replay(&x, &mut arena); // warm-up
        let seq = median((0..reps).map(|_| plan.replay(&x, &mut arena).total_ms).collect());
        print!(
            "{:<14} {:>5} {:>9} {:>9.2} ms",
            name,
            plan.wave_count(),
            plan.max_wave_width(),
            seq
        );
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let _ = plan.replay_on(&x, &mut arena, &pool);
            let par = median(
                (0..reps)
                    .map(|_| plan.replay_on(&x, &mut arena, &pool).total_ms)
                    .collect(),
            );
            print!("  {par:>7.2} ms {:>4.2}x", seq / par.max(1e-9));
        }
        println!();
    }
    println!("\n(speedup tracks max wavefront width; concat/pool barriers cap it)");
}
