//! Wavefront scheduling bench: sequential `ExecPlan::replay` vs the
//! barrier wavefront `replay_on` vs the dep-counted work-stealing
//! `replay_tasked` (intra-op GEMM partitioning included), on branchy
//! models (inception towers, residual legs). The barrier replay only
//! wins on waves wider than one; the tasked scheduler additionally
//! overlaps waves of unbalanced depth and splits big GEMMs when the
//! ready set is narrow — `benches/steal.rs` isolates that case.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::planner::Arena;
use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::models;
use bonseyes::util::stats::median;
use bonseyes::util::threadpool::ThreadPool;

fn main() {
    common::banner(
        "wavefront",
        "parallel branch execution on the shared worker pool",
    );
    let reps = common::reps().max(3);
    println!(
        "{:<14} {:>5} {:>9} {:>12} {:>21} {:>21}",
        "model", "waves", "max-width", "seq ms", "barrier 2t/4t", "tasked 2t/4t"
    );
    for name in ["inceptionette", "googlenet", "squeezenet"] {
        let (g, w) = models::by_name(name, 42).expect("zoo model");
        let p = Prepared::new(g, w, Platform::pi4()).expect("prepared");
        let a = f32_baseline(&p);
        let plan = p.plan(&a, 1).expect("plan");
        let mut arena = Arena::for_plan(&plan);
        let x = common::image_input(&p.graph, 7);
        let _ = plan.replay(&x, &mut arena); // warm-up
        let seq = median((0..reps).map(|_| plan.replay(&x, &mut arena).total_ms).collect());
        print!(
            "{:<14} {:>5} {:>9} {:>9.2} ms",
            name,
            plan.wave_count(),
            plan.max_wave_width(),
            seq
        );
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let _ = plan.replay_on(&x, &mut arena, &pool);
            let par = median(
                (0..reps)
                    .map(|_| plan.replay_on(&x, &mut arena, &pool).total_ms)
                    .collect(),
            );
            print!("  {par:>7.2} ms {:>4.2}x", seq / par.max(1e-9));
        }
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let _ = plan.replay_tasked(&x, &mut arena, &pool);
            let tasked = median(
                (0..reps)
                    .map(|_| plan.replay_tasked(&x, &mut arena, &pool).total_ms)
                    .collect(),
            );
            print!("  {tasked:>7.2} ms {:>4.2}x", seq / tasked.max(1e-9));
        }
        println!();
    }
    println!("\n(barrier speedup tracks max wavefront width; the tasked scheduler");
    println!(" also overlaps waves and partitions big GEMMs on narrow ready sets)");
}
