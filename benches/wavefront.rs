//! Wavefront scheduling bench: sequential `ExecPlan::replay` vs the
//! barrier wavefront `replay_on` vs the dep-counted tasked scheduler,
//! on branchy models (inception towers, residual legs). The tasked
//! scheduler is shown both ways: "fresh" re-derives the schedule every
//! replay, "trace" records a `ScheduleTrace` once and replays it with
//! epoch-counter resets (the serving steady state). The barrier replay
//! only wins on waves wider than one; the tasked scheduler additionally
//! overlaps waves of unbalanced depth and splits big GEMMs when the
//! ready set is narrow — `benches/steal.rs` isolates that case.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::planner::Arena;
use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::models;
use bonseyes::util::stats::median;
use bonseyes::util::threadpool::ThreadPool;

fn main() {
    common::banner(
        "wavefront",
        "parallel branch execution on the shared worker pool",
    );
    let reps = if common::quick() { 1 } else { common::reps().max(3) };
    let names: &[&str] = if common::quick() {
        &["inceptionette"]
    } else {
        &["inceptionette", "googlenet", "squeezenet"]
    };
    println!(
        "{:<14} {:>5} {:>9} {:>12} {:>21} {:>21} {:>21}",
        "model", "waves", "max-width", "seq ms", "barrier 2t/4t", "fresh 2t/4t", "trace 2t/4t"
    );
    for name in names {
        let (g, w) = models::by_name(name, 42).expect("zoo model");
        let p = Prepared::new(g, w, Platform::pi4()).expect("prepared");
        let a = f32_baseline(&p);
        let plan = p.plan(&a, 1).expect("plan");
        let mut arena = Arena::for_plan(&plan);
        let x = common::image_input(&p.graph, 7);
        let _ = plan.replay(&x, &mut arena); // warm-up
        let seq = median((0..reps).map(|_| plan.replay(&x, &mut arena).total_ms).collect());
        print!(
            "{:<14} {:>5} {:>9} {:>9.2} ms",
            name,
            plan.wave_count(),
            plan.max_wave_width(),
            seq
        );
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let _ = plan.replay_on(&x, &mut arena, &pool);
            let par = median(
                (0..reps)
                    .map(|_| plan.replay_on(&x, &mut arena, &pool).total_ms)
                    .collect(),
            );
            print!("  {par:>7.2} ms {:>4.2}x", seq / par.max(1e-9));
        }
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let _ = plan.replay_tasked(&x, &mut arena, &pool);
            let fresh = median(
                (0..reps)
                    .map(|_| plan.replay_tasked(&x, &mut arena, &pool).total_ms)
                    .collect(),
            );
            print!("  {fresh:>7.2} ms {:>4.2}x", seq / fresh.max(1e-9));
        }
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            let mut trace = plan.record_trace(threads);
            let _ = trace.replay_stats(&plan, &x, &mut arena, &pool); // warm-up
            let traced = median(
                (0..reps)
                    .map(|_| trace.replay_stats(&plan, &x, &mut arena, &pool).0.total_ms)
                    .collect(),
            );
            print!("  {traced:>7.2} ms {:>4.2}x", seq / traced.max(1e-9));
        }
        println!();
    }
    println!("\n(barrier speedup tracks max wavefront width; fresh re-derives the tasked");
    println!(" schedule per replay, trace replays the recorded one with epoch resets —");
    println!(" the gap between the two is pure scheduling overhead serving no longer pays)");
}
