//! Fig 14a: LPDNN vs PyTorch on the (resnet-based) body-pose models,
//! CPU single-thread f32 (Jetson-Xavier profile).

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::frameworks::{deploy, DeployOptions, Framework};
use bonseyes::lne::platform::Platform;
use bonseyes::models;

fn main() {
    common::banner("Fig 14a", "LPDNN vs PyTorch — body-pose models, CPU f32");
    let platform = Platform::jetson_xavier();
    let reps = common::reps();
    let mut items = Vec::new();
    for net in ["pose-resnet18", "pose-resnet50"] {
        let (g, w) = models::by_name(net, 3).unwrap();
        let x = common::image_input(&g, 2);
        let opts = DeployOptions {
            episodes: common::scaled(36, 10),
            explore_episodes: common::scaled(14, 5),
            ..Default::default()
        };
        let pt = deploy(Framework::PyTorch, &g, &w, platform.clone(), &x, &opts).unwrap();
        let lp = deploy(Framework::Lpdnn, &g, &w, platform.clone(), &x, &opts).unwrap();
        let pt_ms = pt.latency_ms(&x, reps.min(2)).expect("plannable assignment");
        let lp_ms = lp.latency_ms(&x, reps).expect("plannable assignment");
        eprintln!("{net}: pytorch {pt_ms:.0} ms vs lpdnn {lp_ms:.0} ms ({:.1}x)", pt_ms / lp_ms);
        items.push((format!("{net}/pytorch"), pt_ms));
        items.push((format!("{net}/lpdnn"), lp_ms));
    }
    println!("{}", report::barchart(
        "Fig 14a — CPU inference time (lower is better)", &items, "ms"));
    println!("paper shape: LPDNN amply outperforms PyTorch on CPU (up to 15x on resnet18).");
}
