//! Table 5: DS_CNN variants of the NAS architectures — the paper adapts the
//! Table-4 CNN frontier to depthwise-separable form; each DS model keeps
//! most of the accuracy at ~10-30x fewer MFP_ops.

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::nas::evaluator::{surrogate_accuracy, Surrogate};
use bonseyes::nas::space::{paper_arch, KwsArch};
use bonseyes::nas::{flops, search, NasConfig};

fn main() {
    common::banner("Table 5", "optimized DS_CNN architectures");
    // reproduce the paper's method: take the CNN frontier, flip to DS
    let cfg = NasConfig { trials: common::scaled(200, 60), ds: false, ..Default::default() };
    let cnn = search(&cfg, &mut Surrogate).unwrap();
    let mut rows = Vec::new();
    for &i in &cnn.frontier {
        let mut a = cnn.candidates[i].arch.clone();
        a.ds = true;
        rows.push(vec![
            a.describe(),
            format!("{:.1}%", surrogate_accuracy(&a)),
            format!("{:.1}", flops::mflops(&a)),
            format!("{:.1}", flops::size_kb(&a)),
        ]);
    }
    // paper rows
    let seed = KwsArch { ds: true, convs: vec![(3, 100); 6] };
    rows.push(vec![
        "(seed DS, paper)".into(),
        "90.6% paper".into(),
        format!("{:.1}", flops::mflops(&seed)),
        format!("{:.1}", flops::size_kb(&seed)),
    ]);
    for (name, acc) in [("ds_kws1", "92.6%"), ("ds_kws3", "91.2%"), ("ds_kws9", "91.3%")] {
        let a = paper_arch(name).unwrap();
        rows.push(vec![
            format!("(paper {name})"),
            format!("{acc} paper"),
            format!("{:.1}", flops::mflops(&a)),
            format!("{:.1}", flops::size_kb(&a)),
        ]);
    }
    println!(
        "{}",
        report::table(
            "Table 5 — DS_CNN adaptations of the NAS frontier",
            &["architecture", "TOP-1 (surrogate)", "MFP_ops", "size KB"],
            &rows
        )
    );
    println!("paper shape: DS variants beat the DS seed in accuracy at ~6-10x fewer ops.");
}
