//! Fig 13b: per-layer quantization analysis on kws1 — int8-GEMM speedup
//! over f32 GEMM, with Winograd f32 as the shadowing comparison.

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::lne::engine::Prepared;
use bonseyes::lne::graph::LayerKind;
use bonseyes::lne::passes;
use bonseyes::lne::platform::Platform;
use bonseyes::lne::plugin::{applicable, Assignment, ConvImpl};
use bonseyes::lne::quant_explore::explore;

fn main() {
    common::banner("Fig 13b", "per-layer int8 vs GEMM f32 vs Winograd f32 (kws1)");
    let m = common::manifest();
    let (g0, w0) = common::kws_model(&m, "kws1");
    let (g, w) = passes::optimize(&g0, &w0);
    let p = Prepared::new(g, w, Platform::jetson_nano()).unwrap();
    let x = common::kws_input(&m, 5);
    let reps = common::reps();

    // median per-layer time under a uniform assignment: plan once per
    // assignment, replay hot across the repetitions
    let measure_layers = |a: &Assignment| -> Vec<Vec<f64>> {
        let plan = p.plan(a, x.n()).expect("plannable graph");
        let mut arena = bonseyes::lne::planner::Arena::for_plan(&plan);
        let mut samples: Vec<Vec<f64>> = vec![Vec::new(); p.graph.layers.len()];
        for _ in 0..reps {
            let r = plan.replay(&x, &mut arena);
            for (i, &t) in r.layer_ms.iter().enumerate() {
                samples[i].push(t);
            }
        }
        samples
    };
    let median = bonseyes::util::stats::median;

    let mk_uniform = |impl_: ConvImpl| {
        let mut a = Assignment::default_for(&p.graph);
        for (i, l) in p.graph.layers.iter().enumerate() {
            let ch = applicable(&l.kind, &p.platform);
            if ch.is_empty() {
                continue;
            }
            a.choices[i] = Some(if ch.contains(&impl_) { impl_ } else { ch[0] });
        }
        a
    };
    let f32_t = measure_layers(&mk_uniform(ConvImpl::GemmRef));
    let i8_t = measure_layers(&mk_uniform(ConvImpl::Int8Gemm));
    let wino_t = measure_layers(&mk_uniform(ConvImpl::Winograd));

    let mut items_speedup = Vec::new();
    let mut rows = Vec::new();
    for (i, l) in p.graph.layers.iter().enumerate() {
        if !matches!(l.kind, LayerKind::Conv { .. }) {
            continue;
        }
        let f = median(f32_t[i].clone());
        let q = median(i8_t[i].clone());
        let wn = median(wino_t[i].clone());
        items_speedup.push((l.name.clone(), f / q));
        let wino_avail = applicable(&l.kind, &p.platform).contains(&ConvImpl::Winograd);
        rows.push(vec![
            l.name.clone(),
            format!("{f:.3}"),
            format!("{q:.3} ({:+.0}%)", (f / q - 1.0) * 100.0),
            if wino_avail {
                format!("{wn:.3} ({:+.0}%)", (f / wn - 1.0) * 100.0)
            } else {
                "n/a".into()
            },
        ]);
    }
    println!("{}", report::table(
        "Fig 13b — per-layer latency on kws1 (ms)",
        &["layer", "GEMM f32", "int8", "Winograd f32"], &rows));
    println!("{}", report::barchart(
        "int8 speedup over GEMM f32 per layer (>1 = faster)", &items_speedup, "x"));

    // accuracy-aware mixed selection (the §6.2.5 explorer): candidates
    // pass per-layer, then the joint re-run rolls back compounding layers
    let e = explore(&p, &x);
    let candidates = e.quantized_layers(0.05);
    let a = e.select(&p, 0.05);
    let selected: Vec<&str> = e
        .reports
        .iter()
        .filter(|r| a.choices[r.layer] == Some(ConvImpl::Int8Gemm))
        .map(|r| r.name.as_str())
        .collect();
    println!("quantization explorer (5% deviation budget) candidates: {candidates:?}");
    println!("  joint-budget selection (after rollback):             {selected:?}");
    println!("paper shape: int8 usually-but-not-always beats f32 GEMM; Winograd f32");
    println!("shadows both on the 3x3 compute-heavy layers.");
}
