//! Shared helpers for the table/figure benches. Every bench is a
//! `harness = false` binary built on `bonseyes::bench` (criterion is
//! unavailable offline; the harness mirrors the paper's method: warm-up
//! run discarded, then averaged repeats, single thread, §8.2).
#![allow(dead_code)]

use bonseyes::lne::graph::{Graph, Weights};
use bonseyes::models;
use bonseyes::models::kws::build_graph;
use bonseyes::runtime::manifest::Manifest;
use bonseyes::tensor::Tensor;
use bonseyes::util::rng::Rng;
use std::path::PathBuf;

pub fn manifest() -> Manifest {
    skip_quick_without_artifacts();
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    Manifest::load(&p).expect("run `make artifacts` first")
}

pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// KWS LNE model (graph + random weights) from the manifest arch specs.
pub fn kws_model(m: &Manifest, name: &str) -> (Graph, Weights) {
    let arch = m.arch(name).unwrap_or_else(|| panic!("arch {name} missing"));
    let g = build_graph(arch, m.mel_bands, m.frames, m.num_classes);
    let w = models::random_weights(&g, 42);
    (g, w)
}

/// MFCC-shaped calibration input [1, 1, mel, frames].
pub fn kws_input(m: &Manifest, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::randn(&[1, 1, m.mel_bands, m.frames], 1.0, &mut rng)
}

/// Image input for a zoo model.
pub fn image_input(g: &Graph, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::randn(&[1, g.input.0, g.input.1, g.input.2], 1.0, &mut rng)
}

/// CI smoke-mode toggle (BONSEYES_BENCH_QUICK=1): every bench runs its
/// real code paths at minimum size — one rep, fast-mode scaling, smallest
/// model set — so CI *executes* the benches on every push instead of
/// merely building them. Numbers printed in quick mode are meaningless;
/// the mode exists to catch bench bit-rot and runtime panics.
pub fn quick() -> bool {
    std::env::var("BONSEYES_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Quick-mode guard for benches that need `make artifacts` outputs: the
/// CI smoke has none, so skip cleanly (exit 0) instead of panicking.
/// Outside quick mode, missing artifacts still fail loudly.
pub fn skip_quick_without_artifacts() {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if quick() && !p.exists() {
        println!("BONSEYES_BENCH_QUICK=1 and no artifacts; skipping bench");
        std::process::exit(0);
    }
}

/// Fast-mode toggle (BONSEYES_BENCH_FAST=1 shrinks everything; implied
/// by quick mode).
pub fn fast() -> bool {
    quick() || std::env::var("BONSEYES_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn scaled(normal: usize, fast_value: usize) -> usize {
    if fast() {
        fast_value
    } else {
        normal
    }
}

pub fn reps() -> usize {
    if quick() {
        1
    } else {
        scaled(5, 2)
    }
}

/// Paper-style banner.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
    println!("(paper values shown for shape comparison; absolute times are");
    println!(" host-CPU measurements of the from-scratch substrate, DESIGN.md §3)");
}
