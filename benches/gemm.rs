//! Packed-panel GEMM microkernel bench (ISSUE 6): GFLOP/s of the
//! reference, cache-blocked, and packed-microkernel f32 GEMMs on the
//! conv-lowered shapes of the acceptance models (kws, squeezenet,
//! inceptionette). The packed column runs with the per-platform
//! autotuned tile parameters; the acceptance bar is packed >= 1.5x
//! blocked on these shapes.

#[path = "common.rs"]
mod common;

use bonseyes::lne::platform::Platform;
use bonseyes::lne::primitives::gemm::{bpack_words, gemm_blocked, gemm_packed, gemm_ref, pack_a};
use bonseyes::util::rng::Rng;
use std::time::Instant;

/// Conv-as-GEMM shapes `(label, m, k, n)`: m = output channels,
/// k = in_ch * kh * kw, n = out_h * out_w.
const SHAPES: &[(&str, usize, usize, usize)] = &[
    ("kws conv", 48, 432, 1250),
    ("squeezenet expand3", 128, 288, 196),
    ("squeezenet early", 64, 576, 784),
    ("inceptionette tower", 64, 288, 256),
];

/// Best-of-reps wall time of one call (warm-up rep outside the clock).
fn time(mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..common::reps().max(3) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    common::banner("gemm", "packed-panel microkernel GFLOP/s vs ref and blocked");
    let pi3 = Platform::pi3();
    let pi4 = Platform::pi4();
    println!("autotuned tiles: pi3 {:?}", pi3.pack_params());
    println!("                 pi4 {:?}", pi4.pack_params());
    let params = pi4.pack_params();
    let blk = pi4.blocking;
    println!(
        "\n{:<20} {:<13} {:>9} {:>9} {:>10} {:>9}",
        "shape", "m x k x n", "ref GF/s", "blk GF/s", "pack GF/s", "pack/blk"
    );
    for &(label, m, k, n) in SHAPES {
        let mut rng = Rng::new(11);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        let flops = 2.0 * (m * k * n) as f64;
        let t_ref = time(|| gemm_ref(m, k, n, &a, &b, None, &mut c));
        let t_blk = time(|| gemm_blocked(m, k, n, &a, &b, None, &mut c, blk));
        // weight panels packed once up front, exactly like the planner
        let pa = pack_a(m, k, &a, params.mr);
        let mut bpack = vec![0.0f32; bpack_words(params)];
        let t_pack = time(|| {
            let _ = gemm_packed(k, n, 0..m, &pa, &b, None, &mut c, params, &mut bpack);
        });
        println!(
            "{label:<20} {:<13} {:>9.2} {:>9.2} {:>10.2} {:>8.2}x",
            format!("{m}x{k}x{n}"),
            flops / t_ref / 1e9,
            flops / t_blk / 1e9,
            flops / t_pack / 1e9,
            t_blk / t_pack.max(1e-12),
        );
    }
    println!("\n(pack/blk is the packed-microkernel speedup over the cache-blocked");
    println!(" GEMM at the same kc — the same numbers, faster; acceptance >= 1.5x)");
}
