//! Packed-panel GEMM microkernel bench (ISSUEs 6 and 10): GFLOP/s of the
//! reference, cache-blocked, scalar-packed and SIMD-packed f32 GEMMs on
//! the conv-lowered shapes of the acceptance models (kws, squeezenet,
//! inceptionette), plus an i8 GOP/s pair. The packed columns run with the
//! per-platform autotuned tile parameters; the acceptance bars are
//! packed >= 1.5x blocked and SIMD > scalar packed on these shapes. The
//! %peak column divides the SIMD-packed rate by a board-nominal
//! single-core peak for the platform profile — a shape-comparison
//! estimate (the measurement runs on the host CPU), not a host roofline.

#[path = "common.rs"]
mod common;

use bonseyes::lne::platform::Platform;
use bonseyes::lne::primitives::gemm::{
    bpack_words, gemm_blocked, gemm_packed_with, gemm_ref, pack_a, KernelBackend,
};
use bonseyes::lne::primitives::int8::{bpack_bytes, gemm_i8_packed_with, pack_a_i8};
use bonseyes::util::rng::Rng;
use std::time::Instant;

/// Conv-as-GEMM shapes `(label, m, k, n)`: m = output channels,
/// k = in_ch * kh * kw, n = out_h * out_w.
const SHAPES: &[(&str, usize, usize, usize)] = &[
    ("kws conv", 48, 432, 1250),
    ("squeezenet expand3", 128, 288, 196),
    ("squeezenet early", 64, 576, 784),
    ("inceptionette tower", 64, 288, 256),
];

/// Board-nominal single-core f32 peak GFLOP/s per platform profile
/// (clock x 128-bit f32 lanes x 2 flops/cycle, rounded): the denominator
/// of the %peak estimate.
fn nominal_peak_gflops(name: &str) -> f64 {
    match name {
        "pi3" => 9.6,           // Cortex-A53 @ 1.2 GHz
        "pi4" => 12.0,          // Cortex-A72 @ 1.5 GHz
        "jetson-nano" => 11.4,  // Cortex-A57 @ 1.43 GHz
        "jetson-xavier" => 17.3, // Carmel @ 2.2 GHz
        _ => 12.0,
    }
}

/// Best-of-reps wall time of one call (warm-up rep outside the clock).
fn time(mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..common::reps().max(3) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    common::banner("gemm", "packed-panel microkernel GFLOP/s: ref / blocked / scalar / SIMD");
    let det = KernelBackend::detected();
    let act = KernelBackend::active();
    println!("kernel backend: detected {} / active {}", det.name(), act.name());
    let pi3 = Platform::pi3();
    let pi4 = Platform::pi4();
    println!("autotuned tiles ({}): pi3 {:?}", act.name(), pi3.pack_params());
    println!("                {}   pi4 {:?}", " ".repeat(act.name().len()), pi4.pack_params());
    let params = pi4.pack_params();
    let blk = pi4.blocking;
    let peak = nominal_peak_gflops(&pi4.name);
    println!(
        "\n{:<20} {:<13} {:>8} {:>8} {:>9} {:>9} {:>9} {:>6}",
        "shape", "m x k x n", "ref GF/s", "blk GF/s", "scal GF/s", "simd GF/s", "simd/scal", "%peak"
    );
    let mut simd_wins = 0usize;
    for &(label, m, k, n) in SHAPES {
        let mut rng = Rng::new(11);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        let flops = 2.0 * (m * k * n) as f64;
        let t_ref = time(|| gemm_ref(m, k, n, &a, &b, None, &mut c));
        let t_blk = time(|| gemm_blocked(m, k, n, &a, &b, None, &mut c, blk));
        // weight panels packed once up front, exactly like the planner
        let pa = pack_a(m, k, &a, params.mr);
        let mut bpack = vec![0.0f32; bpack_words(params)];
        let t_scal = time(|| {
            let _ = gemm_packed_with(
                KernelBackend::Scalar, k, n, 0..m, &pa, &b, None, &mut c, params, &mut bpack,
            );
        });
        let t_simd = time(|| {
            let _ = gemm_packed_with(det, k, n, 0..m, &pa, &b, None, &mut c, params, &mut bpack);
        });
        let gf_simd = flops / t_simd / 1e9;
        if t_simd < t_scal {
            simd_wins += 1;
        }
        println!(
            "{label:<20} {:<13} {:>8.2} {:>8.2} {:>9.2} {:>9.2} {:>8.2}x {:>5.0}%",
            format!("{m}x{k}x{n}"),
            flops / t_ref / 1e9,
            flops / t_blk / 1e9,
            flops / t_scal / 1e9,
            gf_simd,
            t_scal / t_simd.max(1e-12),
            100.0 * gf_simd / peak,
        );
    }
    println!(
        "\nSIMD ({}) beats scalar packed on {}/{} shapes (same autotuned tile, bit-identical results)",
        det.name(),
        simd_wins,
        SHAPES.len()
    );

    // i8 widening-MAC pair on the same shapes (GOP/s of i8xi8->i32 MACs)
    println!(
        "\n{:<20} {:<13} {:>12} {:>12} {:>9}",
        "shape (i8)", "m x k x n", "scal GOP/s", "simd GOP/s", "simd/scal"
    );
    for &(label, m, k, n) in SHAPES {
        let mut rng = Rng::new(13);
        let a: Vec<i8> = (0..m * k).map(|_| rng.below(255) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| rng.below(255) as i8).collect();
        let mut c = vec![0i32; m * n];
        let ops = 2.0 * (m * k * n) as f64;
        let pa = pack_a_i8(m, k, &a, params.mr);
        let mut bpack = vec![0i8; bpack_bytes(params)];
        let t_scal = time(|| {
            let _ = gemm_i8_packed_with(
                KernelBackend::Scalar, k, n, 0..m, &pa, &b, &mut c, params, &mut bpack,
            );
        });
        let t_simd = time(|| {
            let _ = gemm_i8_packed_with(det, k, n, 0..m, &pa, &b, &mut c, params, &mut bpack);
        });
        println!(
            "{label:<20} {:<13} {:>12.2} {:>12.2} {:>8.2}x",
            format!("{m}x{k}x{n}"),
            ops / t_scal / 1e9,
            ops / t_simd / 1e9,
            t_scal / t_simd.max(1e-12),
        );
    }
    println!("\n(scal/simd run the same packed kernel and tile with the microkernel");
    println!(" backend forced; %peak is simd GF/s over the profile's board-nominal");
    println!(" single-core peak — an estimate for shape comparison, measured on host)");
}
