//! Fig 13a: LPDNN vs Caffe on the six KWS networks (Jetson-Nano profile,
//! single thread, f32). Series: Caffe (GEMM baseline), LPDNN per-library
//! uniforms, and LPDNN+QS-DNN — which must win on every network.

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::frameworks::{deploy, DeployOptions, Framework};
use bonseyes::lne::platform::Platform;
use bonseyes::lne::plugin::{ConvImpl, DesignSpace};
use bonseyes::qsdnn::measure;

fn main() {
    common::banner("Fig 13a", "LPDNN vs Caffe on the KWS family (1 s audio)");
    let m = common::manifest();
    let nets = ["kws1", "kws3", "kws9", "ds_kws1", "ds_kws3", "ds_kws9"];
    let platform = Platform::jetson_nano();
    let reps = common::reps();
    let mut groups = Vec::new();
    let mut qs_wins = 0;
    for net in nets {
        let (g, w) = common::kws_model(&m, net);
        let x = common::kws_input(&m, 9);
        let opts = DeployOptions {
            episodes: common::scaled(80, 16),
            explore_episodes: common::scaled(32, 8),
            ..Default::default()
        };
        let caffe = deploy(Framework::Caffe, &g, &w, platform.clone(), &x, &opts).unwrap();
        let lpdnn = deploy(Framework::Lpdnn, &g, &w, platform.clone(), &x, &opts).unwrap();
        let caffe_ms = caffe.latency_ms(&x, reps).expect("plannable assignment");
        let lpdnn_ms = lpdnn.latency_ms(&x, reps).expect("plannable assignment");
        // per-library uniforms measured on the optimized graph
        let space = DesignSpace::build(&lpdnn.prepared.graph, &platform);
        let mut items = vec![("caffe".to_string(), caffe_ms)];
        let mut best_uniform = f64::MAX;
        for lib in [ConvImpl::GemmRef, ConvImpl::GemmBlocked, ConvImpl::Winograd, ConvImpl::Direct] {
            let a = space.uniform(&lpdnn.prepared.graph, lib);
            let t = measure(&lpdnn.prepared, &x, &a, reps).expect("plannable assignment");
            best_uniform = best_uniform.min(t);
            items.push((format!("lpdnn-{}", lib.name()), t));
        }
        items.push(("lpdnn-qsdnn".to_string(), lpdnn_ms));
        if lpdnn_ms <= best_uniform * 1.05 {
            qs_wins += 1;
        }
        eprintln!(
            "{net}: caffe {caffe_ms:.2} ms, qsdnn {lpdnn_ms:.2} ms ({:.1}x)",
            caffe_ms / lpdnn_ms
        );
        groups.push((net.to_string(), items));
    }
    println!("{}", report::grouped_barchart(
        "Fig 13a — inference time per KWS network (lower is better)",
        &groups, "ms"));
    println!("QS-DNN matched/beat every uniform library on {qs_wins}/{} nets", nets.len());
    println!("paper shape: Caffe 24-50 ms band vs LPDNN 7-21 ms; QS-DNN <= every library.");
}
