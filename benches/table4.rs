//! Table 4: Pareto-optimal CNN architectures from NAS (TPE + Pareto
//! selection). Default: surrogate evaluator (DESIGN.md §9); run the real
//! PJRT-training evaluator via `cargo bench --bench table4 -- --real-train`
//! (or env BONSEYES_NAS_REAL=1) with a reduced trial budget.

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::ingestion::bta::{Bta, Dataset};
use bonseyes::ingestion::synth;
use bonseyes::ingestion::tools::MfccTool;
use bonseyes::nas::evaluator::{Real, Surrogate};
use bonseyes::nas::space::{paper_arch, KwsArch};
use bonseyes::nas::{flops, search, NasConfig};
use bonseyes::runtime::EngineHandle;
use bonseyes::util::json::Json;

fn build_feature_sets(engine: &EngineHandle) -> (Dataset, Dataset) {
    let (audio, labels) = synth::generate_dataset(16, 10, 5);
    let n = labels.len();
    let mfcc = MfccTool::compute(engine, &audio, n).unwrap();
    let split = n * 8 / 10;
    let feat = 40 * 32;
    let mk = |lo: usize, hi: usize| {
        let mut b = Bta::new();
        b.push("mfcc", &[hi - lo, 40, 32], mfcc[lo * feat..hi * feat].to_vec());
        b.push("labels", &[hi - lo], labels[lo..hi].iter().map(|&l| l as f32).collect());
        b.extra = Json::obj(vec![(
            "classes",
            Json::arr((0..12).map(|i| Json::str(format!("c{i}"))).collect()),
        )]);
        Dataset::from_bta(&b, "mfcc").unwrap()
    };
    (mk(0, split), mk(split, n))
}

fn main() {
    let real = std::env::args().any(|a| a == "--real-train")
        || std::env::var("BONSEYES_NAS_REAL").map(|v| v == "1").unwrap_or(false);
    common::banner("Table 4", "Pareto-optimal CNN architectures from NAS");
    let cfg = NasConfig {
        trials: if real { common::scaled(12, 4) } else { common::scaled(200, 60) },
        ds: false,
        ..Default::default()
    };
    let out = if real {
        let engine = EngineHandle::spawn(common::artifacts_dir()).unwrap();
        let (train, val) = build_feature_sets(&engine);
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let mut eval = Real::new(&root, &train, &val, common::scaled(80, 25));
        search(&cfg, &mut eval).unwrap()
    } else {
        search(&cfg, &mut Surrogate).unwrap()
    };
    let mut rows: Vec<Vec<String>> = out
        .frontier_rows()
        .into_iter()
        .map(|(desc, acc, mf, kb)| {
            vec![desc, format!("{acc:.1}%"), format!("{mf:.1}"), format!("{kb:.1}")]
        })
        .collect();
    // seed + paper rows for shape comparison
    let seed = KwsArch { ds: false, convs: vec![(3, 100); 6] };
    rows.push(vec![
        "(seed, paper: 4x10/3x3,100)".into(),
        "94.2% paper".into(),
        format!("{:.1}", flops::mflops(&seed)),
        format!("{:.1}", flops::size_kb(&seed)),
    ]);
    for name in ["kws1", "kws3", "kws9"] {
        let a = paper_arch(name).unwrap();
        rows.push(vec![
            format!("(paper {name}: {})", a.describe()),
            match name {
                "kws1" => "95.1% paper".into(),
                "kws3" => "94.1% paper".into(),
                _ => "93.4% paper".into(),
            },
            format!("{:.1}", flops::mflops(&a)),
            format!("{:.1}", flops::size_kb(&a)),
        ]);
    }
    println!(
        "{}",
        report::table(
            &format!(
                "Table 4 — NAS Pareto frontier ({} evaluator, {} candidates)",
                if real { "real PJRT-trained" } else { "surrogate" },
                out.candidates.len()
            ),
            &["architecture", "TOP-1", "MFP_ops", "size KB"],
            &rows
        )
    );
    println!("paper shape: frontier dominates the seed (better acc at 2.6-15x fewer ops).");
}
