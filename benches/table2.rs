//! Table 2: KWS trained models under Q (16-bit) and S (sparsification) —
//! accuracy / sparsity / size. Runs the real pipeline: synthetic
//! speech-commands import -> MFCC (pallas/PJRT) -> train (PJRT train-step)
//! -> Q/S tools -> accuracy benchmark. Expected shape: Q and S cost < 0.7%
//! accuracy; Q halves size; Q+S can slightly beat S (quantization
//! regularizes).

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::pipeline::artifact::ArtifactStore;
use bonseyes::pipeline::workflow::{run, Workflow};
use bonseyes::runtime::EngineHandle;
use bonseyes::toolset::builtin_registry;
use bonseyes::util::json::Json;

fn main() {
    common::banner("Table 2", "trained KWS models with Q/S compression");
    common::skip_quick_without_artifacts();
    let engine = EngineHandle::spawn(common::artifacts_dir()).unwrap();
    let store_dir = std::env::temp_dir().join("bonseyes-table2");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = ArtifactStore::open(&store_dir).unwrap();
    let reg = builtin_registry();
    let iters = common::scaled(120, 40);
    let per_class = common::scaled(40, 12);
    // paper trains the two seeds; at our CPU budget the DS seed is the
    // honest full run and the CNN seed is reduced-iteration (DESIGN.md §9)
    let archs: &[(&str, usize)] = if common::fast() {
        &[("ds_kws9", 40)]
    } else {
        &[("ds_cnn_seed", 120), ("kws3", 120)]
    };
    let mut rows = Vec::new();
    for (arch, iterations) in archs {
        let iterations = (*iterations).min(iters.max(20));
        let wf_json = format!(
            r#"{{"name":"table2-{arch}","steps":[
  {{"tool":"speech-commands-import","params":{{"per_class":{per_class},"seed":5}},"outputs":{{"data":"raw"}}}},
  {{"tool":"partition","params":{{"val_frac":0.1,"test_frac":0.2}},"inputs":{{"data":"raw"}},
    "outputs":{{"train":"r-train","val":"r-val","test":"r-test"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-train"}},"outputs":{{"features":"f-train"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-val"}},"outputs":{{"features":"f-val"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-test"}},"outputs":{{"features":"f-test"}}}},
  {{"tool":"train-kws","params":{{"arch":"{arch}","iterations":{iterations}}},
    "inputs":{{"train":"f-train","val":"f-val"}},"outputs":{{"model":"m-{arch}"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"m-{arch}","test":"f-test"}},"outputs":{{"report":"rep-{arch}"}}}},
  {{"tool":"quantize-model","inputs":{{"model":"m-{arch}"}},"outputs":{{"model":"m-{arch}-q"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"m-{arch}-q","test":"f-test"}},"outputs":{{"report":"rep-{arch}-q"}}}},
  {{"tool":"sparsify-model","params":{{"fraction":0.4}},"inputs":{{"model":"m-{arch}"}},"outputs":{{"model":"m-{arch}-s"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"m-{arch}-s","test":"f-test"}},"outputs":{{"report":"rep-{arch}-s"}}}},
  {{"tool":"sparsify-model","params":{{"fraction":0.4}},"inputs":{{"model":"m-{arch}-q"}},"outputs":{{"model":"m-{arch}-qs"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"m-{arch}-qs","test":"f-test"}},"outputs":{{"report":"rep-{arch}-qs"}}}}
]}}"#
        );
        let wf = Workflow::parse(&wf_json).unwrap();
        run(&wf, &reg, &store, Some(engine.clone()), false).unwrap();
        for (suffix, label) in [("", ""), ("-q", " + Q"), ("-s", " + S"), ("-qs", " + Q + S")] {
            let rep = Json::parse(
                &std::fs::read_to_string(
                    store.dir(&format!("rep-{arch}{suffix}")).join("report.json"),
                )
                .unwrap(),
            )
            .unwrap();
            rows.push(vec![
                format!("{arch}{label}"),
                format!("{:.2}%", rep.get("accuracy").as_f64().unwrap() * 100.0),
                format!("{:.1}%", rep.get("sparsity").as_f64().unwrap() * 100.0),
                format!("{:.0}", rep.get("size_kb").as_f64().unwrap()),
            ]);
        }
    }
    println!(
        "{}",
        report::table(
            "Table 2 — accuracy / sparsity / size under Q and S",
            &["model", "acc", "sparsity", "size KB"],
            &rows
        )
    );
    println!("paper shape: Q/S lose <0.7% acc; Q halves size; Q+S ~ S accuracy.");
}
