//! Scale-out serving load bench (DESIGN.md §14): drive a `ModelRouter`
//! hosting the paper's kws9 LNE model two ways.
//!
//! 1. **Closed-loop knee**: N client threads, each issuing blocking
//!    requests back-to-back. As N grows, throughput climbs until the
//!    replica set saturates and latency takes over — the knee.
//! 2. **Open-loop overload**: requests arrive on a fixed clock at ~2× the
//!    measured single-replica capacity, against a bounded admission queue
//!    (`queue_cap`) and a per-request deadline. The batcher must shed
//!    (QueueFull) or evict (DeadlineExceeded) the excess instead of
//!    letting latency grow without bound; more replicas drain more of the
//!    offered load, so shed% falls as the replica count rises.
//!
//! Numbers are host-CPU measurements; replica scaling needs real cores —
//! on a single-core runner the open-loop table still demonstrates typed
//! shedding, just not throughput gain.
#[path = "common.rs"]
mod common;

use bonseyes::lne::platform::Platform;
use bonseyes::nas::evaluator::lne_prepared;
use bonseyes::nas::space::paper_arch;
use bonseyes::serving::{BatcherConfig, ModelRouter, SubmitError};
use bonseyes::util::rng::Rng;
use bonseyes::util::stats::summarize;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const BUCKETS: &[usize] = &[1, 4, 8];

fn router(replicas: usize, queue_cap: Option<usize>, deadline_ms: Option<f64>) -> Arc<ModelRouter> {
    let arch = paper_arch("kws9").expect("kws9 arch");
    let (p, a) = lne_prepared(&arch, 7, Platform::pi4()).expect("prepare kws9");
    let mut r = ModelRouter::with_threads(2);
    r.register_lne(
        "kws9",
        p,
        a,
        BUCKETS,
        &[],
        BatcherConfig {
            max_wait_ms: 2.0,
            max_batch: 8,
            queue_cap,
            deadline_ms,
            replicas,
        },
    )
    .expect("register kws9");
    Arc::new(r)
}

fn samples(n: usize, input_len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(11);
    (0..n)
        .map(|_| bonseyes::testing::randn_vec(&mut rng, input_len, 1.0))
        .collect()
}

/// Closed-loop: `clients` threads, `per_client` blocking requests each.
/// Returns (throughput req/s, p50 ms, p99 ms).
fn closed_loop(router: &Arc<ModelRouter>, clients: usize, per_client: usize) -> (f64, f64, f64) {
    let input_len = router.input_len(None).expect("input_len");
    let pool = samples(16, input_len);
    let lat = Mutex::new(Vec::<f64>::with_capacity(clients * per_client));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..clients {
            let router = Arc::clone(router);
            let pool = &pool;
            let lat = &lat;
            s.spawn(move || {
                let mut mine = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let x = pool[(w + i) % pool.len()].clone();
                    let t = Instant::now();
                    router.infer(None, x).expect("closed-loop infer");
                    mine.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lat.lock().unwrap().extend(mine);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let lats = lat.into_inner().unwrap();
    let s = summarize(&lats);
    (lats.len() as f64 / wall, s.p50, s.p99)
}

/// Open-loop: offer `total` requests on a fixed clock at `rate` req/s.
/// Returns (achieved req/s, shed, evicted, p99 of completed requests).
fn open_loop(router: &Arc<ModelRouter>, rate: f64, total: usize) -> (f64, u64, u64, f64) {
    let input_len = router.input_len(None).expect("input_len");
    let pool = samples(16, input_len);
    let interval = Duration::from_secs_f64(1.0 / rate.max(1.0));
    let t0 = Instant::now();
    let mut shed = 0u64;
    let mut tickets = Vec::with_capacity(total);
    for i in 0..total {
        let due = t0 + interval * i as u32;
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        match router.infer_async(None, pool[i % pool.len()].clone()) {
            Ok(t) => tickets.push(t),
            Err(SubmitError::QueueFull { .. }) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let mut evicted = 0u64;
    let mut done = Vec::with_capacity(tickets.len());
    for t in tickets {
        match t.wait() {
            Ok(p) => done.push(p.latency_ms),
            Err(SubmitError::DeadlineExceeded) => evicted += 1,
            Err(e) => panic!("unexpected wait error: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let p99 = if done.is_empty() { 0.0 } else { summarize(&done).p99 };
    (done.len() as f64 / wall, shed, evicted, p99)
}

fn main() {
    common::banner("serve_load", "replica sets + admission control under load");
    let per_client = common::scaled(64, 8);
    let quick = common::quick();

    // ---- closed-loop knee (single replica, unbounded, no deadline) ------
    println!("closed-loop knee (1 replica, unbounded queue, no deadline):");
    println!("  clients   throughput      p50       p99");
    let r1 = router(1, None, None);
    let mut capacity = 1.0f64;
    let client_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8, 16] };
    for &c in client_counts {
        let (tput, p50, p99) = closed_loop(&r1, c, per_client);
        capacity = capacity.max(tput);
        println!("  {c:7}   {tput:7.1} rps   {p50:6.2} ms {p99:6.2} ms");
    }
    drop(r1);

    // ---- open-loop overload at 1 / 2 / 4 replicas -----------------------
    // Offer ~2x the measured single-replica capacity so the admission
    // queue (cap 64) must shed; a 20x-median deadline evicts stragglers.
    let offered = (capacity * 2.0).max(20.0);
    let total = if quick { 30 } else { (offered as usize).clamp(200, 2000) };
    let deadline_ms = if quick { 250.0 } else { 20_000.0 / offered.max(1.0) };
    println!(
        "\nopen-loop overload: {offered:.0} rps offered, queue_cap=64, \
         deadline {deadline_ms:.0} ms, {total} requests:"
    );
    println!("  replicas   achieved     shed   evicted   admitted-p99");
    let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    for &n in replica_counts {
        let r = router(n, Some(64), Some(deadline_ms));
        let (ach, shed, evicted, p99) = open_loop(&r, offered, total);
        let shed_pct = 100.0 * shed as f64 / total as f64;
        println!(
            "  {n:8}   {ach:6.1} rps   {shed:4} ({shed_pct:4.1}%)   {evicted:7}   {p99:9.2} ms"
        );
    }
    println!("\n(shed requests fail fast with QueueFull/429 instead of queueing;");
    println!(" replica scaling needs real cores — single-core runners show the");
    println!(" typed shedding behaviour, not the throughput gain)");
}
