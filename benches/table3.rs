//! Table 3: TF Lite vs LPDNN on TF-origin networks. Native-format models
//! perform close to LPDNN; *converted* models keep their unfused/unfolded
//! graphs and fall behind (the paper's conversion-penalty finding).

#[path = "common.rs"]
mod common;

use bonseyes::bench::{report, BenchConfig, Group};
use bonseyes::frameworks::{deploy, DeployOptions, Framework};
use bonseyes::lne::platform::Platform;
use bonseyes::models;

fn main() {
    common::banner("Table 3", "TF Lite vs LPDNN (format-conversion penalty)");
    let nets = ["mobilenet-v2", "googlenet", "resnet50"];
    // mobilenet comes "from TF Lite" (native); the others are converted
    let native = [true, false, false];
    let mut rows = Vec::new();
    for platform in [Platform::pi3(), Platform::pi4()] {
        for (net, &is_native) in nets.iter().zip(native.iter()) {
            let (g, w) = models::by_name(net, 7).unwrap();
            let x = common::image_input(&g, 1);
            let opts = DeployOptions {
                episodes: common::scaled(40, 8),
                explore_episodes: common::scaled(16, 4),
                native_format: is_native,
                seed: 0,
            };
            let mut group = Group::new(net);
            group.cfg = BenchConfig::from_env();
            let lp = deploy(Framework::Lpdnn, &g, &w, platform.clone(), &x, &opts).unwrap();
            let tf = deploy(Framework::TfLite, &g, &w, platform.clone(), &x, &opts).unwrap();
            // plan once per deployment; the timed loop replays hot
            let lp_plan = lp.plan(x.n()).unwrap();
            let mut lp_arena = bonseyes::lne::planner::Arena::for_plan(&lp_plan);
            let tf_plan = tf.plan(x.n()).unwrap();
            let mut tf_arena = bonseyes::lne::planner::Arena::for_plan(&tf_plan);
            let lp_ms = group.bench(&format!("{}/{net}/lpdnn", platform.name), || {
                std::hint::black_box(lp_plan.replay(&x, &mut lp_arena));
            });
            let tf_ms = group.bench(&format!("{}/{net}/tflite", platform.name), || {
                std::hint::black_box(tf_plan.replay(&x, &mut tf_arena));
            });
            rows.push(vec![
                format!("{} ({})", net, if is_native { "from TF Lite" } else { "from TF" }),
                platform.name.clone(),
                format!("{:.0}", lp_ms.mean),
                format!("{:.0}", tf_ms.mean),
                format!("{:.2}x", tf_ms.mean / lp_ms.mean),
            ]);
        }
    }
    println!(
        "{}",
        report::table(
            "Table 3 — inference ms, TF Lite vs LPDNN",
            &["DNN", "platform", "LPDNN ms", "TF Lite ms", "TFLite/LPDNN"],
            &rows
        )
    );
    println!("paper shape: native mobilenet ~parity (1.1x); converted nets ~2x+ slower.");
}
