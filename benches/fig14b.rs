//! Fig 14b: reduced precision on the pose models — naive whole-network F16
//! is *slower* than F32 (conversion overhead), while QS-DNN's learned mixed
//! precision (f32/f16/int8 per layer) is faster. GPU FP16 -> CPU
//! reduced-precision substitution per DESIGN.md §3.

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::frameworks::{deploy, DeployOptions, Framework};
use bonseyes::lne::platform::Platform;
use bonseyes::lne::plugin::{ConvImpl, DesignSpace};
use bonseyes::models;
use bonseyes::qsdnn::measure;

fn main() {
    common::banner("Fig 14b", "F32 vs naive F16 vs learned mixed precision");
    let platform = Platform::jetson_xavier();
    let reps = common::reps();
    let mut items = Vec::new();
    for net in ["pose-resnet18", "pose-resnet50"] {
        let (g, w) = models::by_name(net, 3).unwrap();
        let x = common::image_input(&g, 2);
        let opts = DeployOptions {
            episodes: common::scaled(60, 12),
            explore_episodes: common::scaled(24, 6),
            ..Default::default()
        };
        // PyTorch-sim: f32 direct, and naive all-F16 (out-of-the-box FP16)
        let pt = deploy(Framework::PyTorch, &g, &w, platform.clone(), &x, &opts).unwrap();
        let pt_f32 = pt.latency_ms(&x, reps.min(2)).expect("plannable assignment");
        let space = DesignSpace::build(&pt.prepared.graph, &platform);
        let f16_uniform = space.uniform(&pt.prepared.graph, ConvImpl::F16Gemm);
        let pt_f16 = measure(&pt.prepared, &x, &f16_uniform, reps.min(2)).expect("plannable assignment");
        // LPDNN: f32 blocked baseline and QS-DNN mixed precision
        let lp = deploy(Framework::Lpdnn, &g, &w, platform.clone(), &x, &opts).unwrap();
        let lp_space = DesignSpace::build(&lp.prepared.graph, &platform);
        let lp_f32 =
            measure(&lp.prepared, &x, &lp_space.uniform(&lp.prepared.graph, ConvImpl::GemmBlocked), reps)
                .expect("plannable assignment");
        let lp_mixed = lp.latency_ms(&x, reps).expect("plannable assignment");
        eprintln!(
            "{net}: pt f32 {pt_f32:.0} / pt f16 {pt_f16:.0} / lpdnn f32 {lp_f32:.0} / mixed {lp_mixed:.0} ms"
        );
        items.push((format!("{net}/pytorch-f32"), pt_f32));
        items.push((format!("{net}/pytorch-f16"), pt_f16));
        items.push((format!("{net}/lpdnn-f32"), lp_f32));
        items.push((format!("{net}/lpdnn-mixed"), lp_mixed));
    }
    println!("{}", report::barchart(
        "Fig 14b — reduced-precision inference time (lower is better)", &items, "ms"));
    println!("paper shape: out-of-the-box F16 slower than F32; learned mixed precision");
    println!("up to 65% faster than F32 (ours: int8/f32 mixing on CPU).");
}
