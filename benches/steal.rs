//! Work-stealing scheduler bench: unbalanced inception towers, where the
//! barrier wavefront replay (`replay_on`) stalls every worker at each
//! wave boundary while one deep tower is still running, but the
//! dep-counted tasked replay lets deep branches run ahead and splits
//! large GEMMs into row-range subtasks whenever the ready set is narrow.
//! Two tasked columns separate schedule construction from execution:
//! "fresh" re-derives the schedule every replay (`replay_tasked_stats`,
//! which records a throwaway trace each time), "recorded" records a
//! `ScheduleTrace` once and replays it with epoch-counter resets — the
//! zero-alloc steady state serving runs. The ISSUE 8 acceptance check is
//! recorded beating fresh on this model at >= 2 threads.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::graph::{Graph, LayerKind, Padding};
use bonseyes::lne::planner::Arena;
use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::models::random_weights;
use bonseyes::util::stats::median;
use bonseyes::util::threadpool::ThreadPool;
use std::time::Instant;

/// Inception-style blocks with *unbalanced* tower depths: a 1x1 shortcut
/// tower against a deep 3x3 chain and a mid 5x5 tower, joined by concat.
/// Wave widths shrink to 1 long before the deep chain finishes, so the
/// barrier replay serializes most of the work.
fn unbalanced_towers(blocks: usize) -> Graph {
    let conv = |k: usize| LayerKind::Conv {
        k: (k, k),
        stride: (1, 1),
        pad: Padding::Same,
        relu_fused: true,
    };
    let mut g = Graph::new("unbalanced-towers", (16, 24, 24));
    let mut inp = 0;
    for b in 0..blocks {
        let t1 = g.push_on(&format!("b{b}_1x1"), conv(1), vec![inp], 24);
        let mut deep = inp;
        for i in 0..4 {
            deep = g.push_on(&format!("b{b}_deep{i}"), conv(3), vec![deep], 48);
        }
        let mid = g.push_on(&format!("b{b}_5x5"), conv(5), vec![inp], 32);
        inp = g.push_on(
            &format!("b{b}_cat"),
            LayerKind::Concat,
            vec![t1, deep, mid],
            0,
        );
    }
    g
}

fn main() {
    common::banner(
        "steal",
        "work-stealing + intra-op partitioning on unbalanced inception towers",
    );
    let reps = if common::quick() { 1 } else { common::reps().max(3) };
    let g = unbalanced_towers(if common::quick() { 1 } else { 2 });
    let w = random_weights(&g, 42);
    let p = Prepared::new(g, w, Platform::pi4()).expect("prepared");
    let a = f32_baseline(&p);
    let plan = p.plan(&a, 1).expect("plan");
    plan.validate_schedule().expect("schedule invariant");
    let mut arena = Arena::for_plan(&plan);
    let x = common::image_input(&p.graph, 7);
    let _ = plan.replay(&x, &mut arena); // warm-up
    let seq = median((0..reps).map(|_| plan.replay(&x, &mut arena).total_ms).collect());
    println!(
        "{} steps, {} waves (max width {}), arena {} KB, seq {seq:.2} ms",
        plan.steps.len(),
        plan.wave_count(),
        plan.max_wave_width(),
        plan.arena_bytes() / 1024
    );
    println!(
        "{:>7} {:>13} {:>11} {:>14} {:>10} {:>7} {:>7} {:>9} {:>6}",
        "threads", "barrier ms", "fresh ms", "recorded ms", "record µs", "rec-x", "steals", "subtasks", "parks"
    );
    for threads in [2usize, 4] {
        let pool = ThreadPool::new(threads);
        let _ = plan.replay_on(&x, &mut arena, &pool);
        let barrier = median(
            (0..reps)
                .map(|_| plan.replay_on(&x, &mut arena, &pool).total_ms)
                .collect(),
        );
        // fresh schedule: record + replay a throwaway trace every rep —
        // what every request paid before traces were cached
        let _ = plan.replay_tasked(&x, &mut arena, &pool);
        let fresh = median(
            (0..reps)
                .map(|_| plan.replay_tasked_stats(&x, &mut arena, &pool).0.total_ms)
                .collect(),
        );
        // recorded: one schedule capture, then epoch-reset replays only
        let t0 = Instant::now();
        let mut trace = plan.record_trace(threads);
        let record_us = t0.elapsed().as_secs_f64() * 1e6;
        let _ = trace.replay_stats(&plan, &x, &mut arena, &pool); // warm-up
        let mut steals = 0usize;
        let mut subtasks = 0usize;
        let mut parks = 0usize;
        let recorded = median(
            (0..reps)
                .map(|_| {
                    let (r, s) = trace.replay_stats(&plan, &x, &mut arena, &pool);
                    steals = s.steals;
                    subtasks = s.subtasks;
                    parks = s.parks;
                    r.total_ms
                })
                .collect(),
        );
        println!(
            "{threads:>7} {barrier:>10.2} ms {fresh:>8.2} ms {recorded:>11.2} ms {record_us:>10.1} {:>5.2}x {steals:>7} {subtasks:>9} {parks:>6}",
            fresh / recorded.max(1e-9)
        );
    }
    println!("\n(fresh re-derives the schedule per replay; recorded replays the frozen");
    println!(" trace with epoch-counter resets — rec-x > 1 means the record-once path");
    println!(" wins; record µs is the one-time capture cost a serving session amortizes)");
}
