//! Fig 15: comparison with embedded deployment frameworks — five ImageNet
//! networks x seven baselines + LPDNN x two platform profiles, reported as
//! relative speedup over Caffe (the paper's reference).

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::frameworks::{deploy, DeployOptions, Framework, BASELINES};
use bonseyes::lne::platform::Platform;
use bonseyes::models;

fn main() {
    common::banner("Fig 15", "framework comparison on ImageNet networks (speedup over Caffe)");
    let reps = common::reps().min(3);
    let nets: Vec<&str> = if common::fast() {
        vec!["squeezenet", "mobilenet-v2"]
    } else {
        models::IMAGENET_MODELS.to_vec()
    };
    for platform in [Platform::pi3(), Platform::pi4()] {
        let mut groups = Vec::new();
        let mut lpdnn_wins = 0usize;
        let mut cells = 0usize;
        for net in &nets {
            let (g, w) = models::by_name(net, 11).unwrap();
            let x = common::image_input(&g, 4);
            let opts = DeployOptions {
                episodes: common::scaled(36, 10),
                explore_episodes: common::scaled(14, 5),
                ..Default::default()
            };
            let caffe_ms = deploy(Framework::Caffe, &g, &w, platform.clone(), &x, &opts)
                .unwrap()
                .latency_ms(&x, reps)
                .expect("plannable assignment");
            let mut items = vec![("caffe (1.00x)".to_string(), 1.0f64)];
            let mut best_baseline = 0.0f64;
            for fw in BASELINES.iter().skip(1) {
                // skip Caffe itself
                let d = deploy(*fw, &g, &w, platform.clone(), &x, &opts).unwrap();
                let speedup = caffe_ms / d.latency_ms(&x, reps).expect("plannable assignment");
                best_baseline = best_baseline.max(speedup);
                items.push((fw.name().to_string(), speedup));
            }
            let lp = deploy(Framework::Lpdnn, &g, &w, platform.clone(), &x, &opts).unwrap();
            let lp_speedup = caffe_ms / lp.latency_ms(&x, reps).expect("plannable assignment");
            items.push(("lpdnn".to_string(), lp_speedup));
            cells += 1;
            if lp_speedup >= best_baseline * 0.97 {
                lpdnn_wins += 1;
            }
            eprintln!(
                "[{}] {net}: caffe {caffe_ms:.0} ms; lpdnn {lp_speedup:.2}x (best baseline {best_baseline:.2}x)",
                platform.name
            );
            groups.push((format!("{net} (caffe {caffe_ms:.0} ms)"), items));
        }
        println!("{}", report::grouped_barchart(
            &format!("Fig 15 [{}] — speedup over Caffe (higher is better)", platform.name),
            &groups, "x"));
        println!("LPDNN best-or-tied on {lpdnn_wins}/{cells} networks ({})\n", platform.name);
    }
    println!("paper shape: per-framework wins are spotty; ArmCL & LPDNN stable;");
    println!("LPDNN highest overall and consistent across both platforms.");
}
