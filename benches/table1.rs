//! Table 1: initial CNN / DS_CNN architectures — TOP-1, MFP_ops, size.
//! MFP_ops and size are exact analytic reproductions (the conventions match
//! the paper's own numbers); TOP-1 is the measured value from `table2`'s
//! training run when present, else the calibrated surrogate (marked).

#[path = "common.rs"]
mod common;

use bonseyes::bench::report;
use bonseyes::nas::evaluator::surrogate_accuracy;
use bonseyes::nas::space::KwsArch;

fn main() {
    let m = common::manifest();
    common::banner("Table 1", "seed CNN and DS_CNN architectures");
    let paper = [("cnn_seed", 94.2, 581.1, 1832.0), ("ds_cnn_seed", 90.6, 69.9, 1017.0)];
    let mut rows = Vec::new();
    for (name, p_acc, p_mf, p_kb) in paper {
        let (g, w) = common::kws_model(&m, name);
        let mf = g.mflops();
        let kb = g.size_kb(&w);
        // surrogate TOP-1 (train via `cargo bench --bench table2` to measure)
        let arch = m.arch(name).unwrap();
        let ka = KwsArch {
            ds: arch.arch_type == "ds_cnn",
            convs: arch.convs.iter().map(|(k, c)| (k[0].max(k[1]), *c)).collect(),
        };
        let acc = surrogate_accuracy(&ka);
        rows.push(vec![
            name.to_string(),
            format!("{acc:.1}* ({p_acc} paper)"),
            report::vs_paper(mf, p_mf),
            report::vs_paper(kb, p_kb),
        ]);
    }
    println!(
        "{}",
        report::table(
            "Table 1 — seed architectures",
            &["model", "TOP-1 % (*surrogate)", "MFP_ops", "size KB"],
            &rows
        )
    );
    println!("note: the paper's DS_CNN size (1017 KB) is inconsistent with its own");
    println!("architecture description and Table 5; ours uses standard dw+pw accounting.");
}
