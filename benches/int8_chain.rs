//! Int8 activation-lane bench: an all-int8 conv chain executed three
//! ways — uniform f32 (blocked GEMM), the legacy int8 path that
//! round-trips every activation through f32 (dequantize + requantize the
//! whole patch matrix at each edge), and the i8-resident path that keeps
//! activations quantized between consecutive int8 layers with boundary
//! conversions only (DESIGN.md §7). The delta between the last two is the
//! conversion cost the resident lanes remove.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::graph::{Graph, LayerKind, Padding};
use bonseyes::lne::planner::{Arena, ExecPlan, PlanOptions};
use bonseyes::lne::platform::Platform;
use bonseyes::lne::plugin::{ConvImpl, DesignSpace};
use bonseyes::models;
use bonseyes::util::stats::median;

fn chain(name: &str, depth: usize, c: usize, hw: usize) -> Graph {
    let mut g = Graph::new(name, (3, hw, hw));
    for i in 0..depth {
        g.push(
            &format!("conv{}", i + 1),
            LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true },
            c,
        );
    }
    g
}

fn bench_plan(plan: &ExecPlan, x: &bonseyes::tensor::Tensor, reps: usize) -> f64 {
    let mut arena = Arena::for_plan(plan);
    let _ = plan.replay(x, &mut arena); // warm-up
    median((0..reps).map(|_| plan.replay(x, &mut arena).total_ms).collect())
}

fn main() {
    common::banner(
        "int8_chain",
        "f32 vs int8-roundtrip vs int8-resident activation lanes",
    );
    let reps = common::reps().max(3);
    println!(
        "{:<18} {:>12} {:>15} {:>15} {:>9}",
        "chain", "f32 ms", "i8-roundtrip", "i8-resident", "vs-rt"
    );
    for (depth, c, hw) in [(4usize, 16usize, 32usize), (6, 32, 24)] {
        let name = format!("{depth}x conv{c}@{hw}");
        let g = chain(&name, depth, c, hw);
        let w = models::random_weights(&g, 42);
        let p = Prepared::new(g.clone(), w, Platform::pi4()).expect("prepared");
        let space = DesignSpace::build(&g, &p.platform);
        let x = common::image_input(&g, 7);

        let f32_plan = p
            .plan(&space.uniform(&g, ConvImpl::GemmBlocked), 1)
            .expect("f32 plan");
        let a_i8 = space.uniform(&g, ConvImpl::Int8Gemm);
        let rt_plan = p
            .plan_with(&a_i8, 1, PlanOptions { int8_resident: false })
            .expect("roundtrip plan");
        let res_plan = p.plan(&a_i8, 1).expect("resident plan");
        assert_eq!(res_plan.i8_resident_steps(), depth);
        assert_eq!(res_plan.lane_conversion_steps(), 2);

        let f = bench_plan(&f32_plan, &x, reps);
        let rt = bench_plan(&rt_plan, &x, reps);
        let res = bench_plan(&res_plan, &x, reps);
        println!(
            "{name:<18} {f:>9.2} ms {rt:>12.2} ms {res:>12.2} ms {:>8.2}x",
            rt / res.max(1e-9)
        );
    }
    println!("\n(vs-rt: i8-resident speedup over the f32 round-trip int8 path;");
    println!(" interior edges skip dequantize + patch-matrix requantize entirely)");
}
