//! Fig 11: QS-DNN learning curve — exploration episodes are noisy/slow,
//! the exploitation phase converges to the fast deployment.

#[path = "common.rs"]
mod common;

use bonseyes::lne::engine::Prepared;
use bonseyes::lne::platform::Platform;
use bonseyes::qsdnn::{search, QsDnnConfig};

fn main() {
    common::banner("Fig 11", "QS-DNN RL optimization (explore -> exploit)");
    let m = common::manifest();
    let (g, w) = common::kws_model(&m, "kws1");
    let p = Prepared::new(g, w, Platform::jetson_nano()).unwrap();
    let x = common::kws_input(&m, 3);
    let episodes = common::scaled(120, 30);
    let cfg = QsDnnConfig {
        episodes,
        explore_episodes: episodes / 2,
        ..Default::default()
    };
    let out = search(&p, &x, &cfg).expect("plannable model");
    // render the curve as per-bucket means
    let bucket = (episodes / 20).max(1);
    let max = out.episode_ms.iter().cloned().fold(f64::MIN, f64::max);
    println!("\nepisode latency (ms), {bucket}-episode buckets; | marks explore->exploit:");
    for (bi, chunk) in out.episode_ms.chunks(bucket).enumerate() {
        let mean = chunk.iter().sum::<f64>() / chunk.len() as f64;
        let bars = "#".repeat(((mean / max) * 50.0).round() as usize);
        let marker = if bi * bucket < cfg.explore_episodes
            && (bi + 1) * bucket >= cfg.explore_episodes
        {
            " <- exploitation starts"
        } else {
            ""
        };
        println!("ep {:>4}-{:<4} | {bars} {mean:.3}{marker}", bi * bucket, (bi + 1) * bucket - 1);
    }
    let explore_mean: f64 = out.episode_ms[..cfg.explore_episodes].iter().sum::<f64>()
        / cfg.explore_episodes as f64;
    println!(
        "\nexplore mean {:.3} ms -> best found {:.3} ms ({:.2}x faster)",
        explore_mean,
        out.best_ms,
        explore_mean / out.best_ms
    );
    println!("paper shape: two-stage curve — noisy plateau, then converging descent.");
}
