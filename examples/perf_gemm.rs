//! §Perf probe: GEMM throughput across shapes (L3 hot path).
use bonseyes::lne::primitives::gemm::{
    bpack_words, gemm_blocked, gemm_packed, gemm_ref, pack_a, Blocking, PackParams,
};
use bonseyes::util::rng::Rng;
use std::time::Instant;

fn main() {
    let shapes = [(96usize, 363usize, 1024usize), (256, 2304, 256), (64, 576, 4096), (1000, 512, 1)];
    let params = PackParams::default();
    let mut rng = Rng::new(0);
    for (m, k, n) in shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        let flops = 2.0 * (m * k * n) as f64;
        let time = |f: &mut dyn FnMut()| {
            f();
            let t0 = Instant::now();
            let mut iters = 0;
            while t0.elapsed().as_secs_f64() < 0.4 { f(); iters += 1; }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let t_ref = time(&mut || gemm_ref(m, k, n, &a, &b, None, &mut c));
        let t_blk = time(&mut || gemm_blocked(m, k, n, &a, &b, None, &mut c, Blocking::default()));
        let pa = pack_a(m, k, &a, params.mr);
        let mut bpack = vec![0.0f32; bpack_words(params)];
        let t_pack = time(&mut || {
            let _ = gemm_packed(k, n, 0..m, &pa, &b, None, &mut c, params, &mut bpack);
        });
        println!("{m}x{k}x{n}: ref {:.2} GF/s, blocked {:.2} GF/s ({:.2}x), packed {:.2} GF/s ({:.2}x)",
                 flops / t_ref / 1e9, flops / t_blk / 1e9, t_ref / t_blk,
                 flops / t_pack / 1e9, t_blk / t_pack);
    }
}
