//! END-TO-END DRIVER (the serving-paper validation required by DESIGN.md):
//! run the complete Bonseyes pipeline — data ingestion -> MFCC -> training
//! (PJRT train-step) -> accuracy benchmark -> Q+S compression -> LPDNN
//! deployment — then stand up the KWS serving stack and push batched
//! requests through it, reporting accuracy, latency percentiles and
//! throughput. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example kws_pipeline_e2e
//!
//! Env: E2E_ARCH (default ds_kws3), E2E_ITERS (default 260),
//!      E2E_PER_CLASS (default 32), E2E_REQUESTS (default 256).

use bonseyes::pipeline::artifact::ArtifactStore;
use bonseyes::pipeline::workflow::{run, Workflow};
use bonseyes::runtime::EngineHandle;
use bonseyes::serving::{BatcherConfig, KwsServer, ModelRouter, ServableModel};
use bonseyes::toolset::builtin_registry;
use bonseyes::http::client;
use bonseyes::util::json::Json;
use bonseyes::util::stats::summarize;
use std::sync::Arc;
use std::time::Instant;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let arch = std::env::var("E2E_ARCH").unwrap_or_else(|_| "ds_kws3".into());
    let iters = env_usize("E2E_ITERS", 260);
    let per_class = env_usize("E2E_PER_CLASS", 32);
    let n_requests = env_usize("E2E_REQUESTS", 256);

    println!("== Bonseyes end-to-end: ingest -> train({arch},{iters}) -> deploy -> serve ==");
    let engine = EngineHandle::spawn("artifacts")?;
    let store_dir = std::env::temp_dir().join("bonseyes-e2e-example");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = ArtifactStore::open(&store_dir)?;
    let reg = builtin_registry();

    // ---- stages 1-3 of the paper's pipeline as one workflow -------------
    let wf = Workflow::parse(&format!(
        r#"{{"name":"kws-e2e","steps":[
  {{"tool":"speech-commands-import","params":{{"per_class":{per_class},"seed":5}},"outputs":{{"data":"raw"}}}},
  {{"tool":"partition","params":{{"val_frac":0.1,"test_frac":0.2}},"inputs":{{"data":"raw"}},
    "outputs":{{"train":"r-train","val":"r-val","test":"r-test"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-train"}},"outputs":{{"features":"f-train"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-val"}},"outputs":{{"features":"f-val"}}}},
  {{"tool":"mfcc-features","inputs":{{"data":"r-test"}},"outputs":{{"features":"f-test"}}}},
  {{"tool":"train-kws","params":{{"arch":"{arch}","iterations":{iters}}},
    "inputs":{{"train":"f-train","val":"f-val"}},"outputs":{{"model":"model"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"model","test":"f-test"}},"outputs":{{"report":"report"}}}},
  {{"tool":"quantize-model","inputs":{{"model":"model"}},"outputs":{{"model":"model-q"}}}},
  {{"tool":"sparsify-model","params":{{"fraction":0.3}},"inputs":{{"model":"model-q"}},"outputs":{{"model":"model-qs"}}}},
  {{"tool":"benchmark-kws","inputs":{{"model":"model-qs","test":"f-test"}},"outputs":{{"report":"report-qs"}}}},
  {{"tool":"deploy-lpdnn","params":{{"episodes":40}},"inputs":{{"model":"model"}},"outputs":{{"app":"app"}}}}
]}}"#
    ))
    .map_err(|e| anyhow::anyhow!(e))?;
    let t0 = Instant::now();
    let report = run(&wf, &reg, &store, Some(engine.clone()), false)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("\npipeline done in {:.1}s:", t0.elapsed().as_secs_f64());
    for s in &report.steps {
        println!("  {:26} {:7.2}s{}", s.tool, s.seconds, if s.skipped { " (skipped)" } else { "" });
    }
    let acc_report = Json::parse(
        &std::fs::read_to_string(store.dir("report").join("report.json"))?,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let acc = acc_report.get("accuracy").as_f64().unwrap_or(0.0);
    let acc_qs = Json::parse(
        &std::fs::read_to_string(store.dir("report-qs").join("report.json"))?,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\ntest accuracy: {:.2}%  (Q+S compressed: {:.2}%)",
             acc * 100.0, acc_qs.get("accuracy").as_f64().unwrap_or(0.0) * 100.0);
    let app = Json::parse(&std::fs::read_to_string(store.dir("app").join("app.json"))?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("LPDNN deployment: {} on {} -> {:.2} ms/inference",
             app.get("arch").as_str().unwrap_or("?"),
             app.get("platform").as_str().unwrap_or("?"),
             app.get("latency_ms").as_f64().unwrap_or(0.0));

    // ---- stage 4: serve the trained model over HTTP with batching -------
    let model = ServableModel::from_artifact(&store.dir("model"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let mut router = ModelRouter::new();
    router.register_pjrt(
        &engine,
        model,
        BatcherConfig { max_wait_ms: 4.0, max_batch: 32, ..Default::default() },
    )?;
    let serving = Arc::new(router);
    let mut server = KwsServer::serve(Arc::clone(&serving), "127.0.0.1:0", 16)?;
    let base = format!("http://{}", server.addr);
    println!("\nserving on {base}; pushing {n_requests} concurrent requests...");

    let t0 = Instant::now();
    let lat = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let correct = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    std::thread::scope(|s| {
        for w in 0..16usize {
            let base = base.clone();
            let lat = Arc::clone(&lat);
            let correct = Arc::clone(&correct);
            s.spawn(move || {
                let per = n_requests / 16;
                for i in 0..per {
                    let class = (w * per + i) % 10;
                    let body = Json::parse(&format!(
                        r#"{{"synthesize": {{"class": {class}, "seed": {}}}}}"#,
                        1000 + w * per + i
                    ))
                    .unwrap();
                    let t = Instant::now();
                    let resp = client::post_json(&format!("{base}/v1/kws"), &body).unwrap();
                    let ms = t.elapsed().as_secs_f64() * 1e3;
                    lat.lock().unwrap().push(ms);
                    let got = resp.json().unwrap().get("class_id").as_usize().unwrap_or(99);
                    if got == class {
                        correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let lats = lat.lock().unwrap().clone();
    let s = summarize(&lats);
    let served_acc =
        correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / lats.len() as f64;
    println!("\n== serving results ==");
    println!("requests      : {}", lats.len());
    println!("throughput    : {:.1} req/s", lats.len() as f64 / wall);
    println!("latency mean  : {:.1} ms   p50 {:.1}  p95 {:.1}  p99 {:.1}  max {:.1}",
             s.mean, s.p50, s.p95, s.p99, s.max);
    println!("served accuracy (keyword classes): {:.1}%", served_acc * 100.0);
    println!("batcher stats : {}", serving.metrics.snapshot());
    server.stop();
    Ok(())
}
