//! IoT-hub integration demo (paper §7, Fig 12): a FIWARE-like hub (context
//! broker + Kurento-like media module) with devices in both scenarios —
//! edge-processing agents inferring locally and pushing results, and a
//! constrained cloud-processing agent offloading raw audio to the hub.
//!
//!     make artifacts && cargo run --release --example iot_edge

use bonseyes::iot::{CloudAgent, ContextBroker, EdgeAgent, MediaModule};
use bonseyes::runtime::EngineHandle;
use bonseyes::serving::{BatcherConfig, ModelRouter, ServableModel};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let engine = EngineHandle::spawn("artifacts")?;
    let mut serving = ModelRouter::new();
    serving.register_pjrt(
        &engine,
        ServableModel::from_init(&engine, "ds_kws9")?,
        BatcherConfig { max_wait_ms: 3.0, ..Default::default() },
    )?;
    let serving = Arc::new(serving);
    let broker = ContextBroker::new();
    let mut hub = MediaModule::serve_hub(Arc::clone(&serving), Arc::clone(&broker), "127.0.0.1:0")?;
    let hub_url = format!("http://{}", hub.addr);
    println!("IoT hub (broker + media module) at {hub_url}\n");

    // scenario A: three edge devices infer locally, results go to the hub
    println!("-- scenario A: edge-processing --");
    for d in 0..3usize {
        let mut agent = EdgeAgent::new(&format!("edge-{d}"), Arc::clone(&serving), &hub_url);
        agent.register().map_err(|e| anyhow::anyhow!(e))?;
        for utterance in 0..2usize {
            let class = (d * 2 + utterance) % 10;
            let m = agent.capture_and_report(class).map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "  edge-{d}: said class {class} -> device heard '{}' ({:.1} ms on-device)",
                m.get("keyword").as_str().unwrap_or("?"),
                m.get("latency_ms").as_f64().unwrap_or(0.0)
            );
        }
    }

    // scenario B: a constrained device offloads raw audio to the hub
    println!("\n-- scenario B: cloud-processing --");
    let mut tiny = CloudAgent::new("sensor-9", &hub_url);
    for class in [1usize, 7] {
        let resp = tiny.capture_and_offload(class, 10).map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "  sensor-9: shipped audio of class {class} -> hub heard '{}' ({:.1} ms hub-side)",
            resp.get("class").as_str().unwrap_or("?"),
            resp.get("latency_ms").as_f64().unwrap_or(0.0)
        );
    }

    // the hub's context view
    println!("\n-- hub context entities --");
    for e in broker.list(None) {
        println!("  [{}] {} {}", e.entity_type, e.id,
                 e.attrs.get("keyword").map(|k| k.to_string()).unwrap_or_default());
    }
    hub.stop();
    Ok(())
}
