//! Quickstart: load the AOT artifacts, extract MFCC features through the
//! pallas kernel via PJRT, and classify a synthetic keyword with a KWS
//! model — the minimal tour of the three-layer stack.
//!
//!     make artifacts && cargo run --release --example quickstart

use bonseyes::ingestion::synth;
use bonseyes::runtime::{EngineHandle, OwnedInput};
use bonseyes::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. open the artifacts (HLO text compiled on the PJRT CPU client)
    let engine = EngineHandle::spawn("artifacts")?;
    let m = engine.manifest.clone();
    println!("loaded {} graphs / {} architectures", m.graphs.len(), m.archs.len());

    // 2. synthesize a keyword utterance ("left" = class 4)
    let class = 4usize;
    let audio = synth::generate(class, m.classes.len() - 2, &mut Rng::new(7));
    println!("synthesized 1 s of '{}' audio ({} samples)", m.classes[class], audio.len());

    // 3. MFCC front-end: the L1 pallas logmel kernel, AOT-lowered, run from rust
    let mfcc = engine
        .run("mfcc_b1", vec![OwnedInput::new(audio, &[1, m.samples])])?
        .remove(0);
    println!("MFCC features: {}x{} (40x32 per the paper §4)", m.mel_bands, m.frames);

    // 4. KWS inference with the ds_kws9 model (He-init here; train it with
    //    `bonseyes pipeline run configs/workflows/kws_e2e.json`)
    let arch = m.arch("ds_kws9").unwrap();
    let params = engine.read_blob(&arch.init_file)?;
    let stats = engine.read_blob(&arch.init_stats_file)?;
    let logits = engine
        .run(
            "ds_kws9_infer_b1",
            vec![
                OwnedInput::new(params, &[arch.n_params]),
                OwnedInput::new(stats, &[arch.n_stats]),
                OwnedInput::new(mfcc, &[1, m.mel_bands, m.frames]),
            ],
        )?
        .remove(0);
    let best = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("logits: {logits:.3?}");
    println!("predicted '{}' (untrained weights — see the kws_pipeline_e2e example)",
             m.classes[best]);
    Ok(())
}
