//! Deploy one network under every framework policy and print the
//! latency ranking — a one-model slice of Fig 15.
//!
//!     cargo run --release --example framework_comparison [model] [platform]
//! defaults: squeezenet pi4

use bonseyes::bench::report;
use bonseyes::frameworks::{deploy, DeployOptions, Framework, BASELINES};
use bonseyes::lne::platform::Platform;
use bonseyes::models;
use bonseyes::tensor::Tensor;
use bonseyes::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("squeezenet");
    let platform = Platform::by_name_or_err(args.get(1).map(|s| s.as_str()).unwrap_or("pi4"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let (g, w) = models::by_name(model, 0)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}; try one of {:?}",
                                       models::IMAGENET_MODELS))?;
    println!("{model} on {}: {:.1} MFLOPs, {:.0} KB, {} layers",
             platform.name, g.mflops(), g.size_kb(&w), g.layers.len());
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[1, g.input.0, g.input.1, g.input.2], 1.0, &mut rng);
    let opts = DeployOptions { episodes: 40, explore_episodes: 16, ..Default::default() };
    let mut items = Vec::new();
    for fw in BASELINES.iter().copied().chain([Framework::Lpdnn]) {
        let d = deploy(fw, &g, &w, platform.clone(), &x, &opts)
            .map_err(|e| anyhow::anyhow!(e))?;
        let ms = d.latency_ms(&x, 5).expect("plannable assignment");
        println!("  {:10} {ms:9.2} ms   [{}]", fw.name(),
                 if fw == Framework::Lpdnn { "QS-DNN searched" } else { "fixed policy" });
        items.push((fw.name().to_string(), ms));
    }
    println!("{}", report::barchart(
        &format!("{model} on {} — lower is better", platform.name), &items, "ms"));
    Ok(())
}
