//! Neural Architecture Search demo (paper §5.3): TPE over the KWS conv
//! space + Pareto selection, printing the accuracy/MFLOPs frontier against
//! the paper's Table-4 rows.
//!
//!     cargo run --release --example nas_search [--ds] [--trials N]

use bonseyes::nas::evaluator::Surrogate;
use bonseyes::nas::space::{paper_arch, KwsArch};
use bonseyes::nas::{flops, search, NasConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds = args.iter().any(|a| a == "--ds");
    let trials = args
        .iter()
        .position(|a| a == "--trials")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let cfg = NasConfig { trials, ds, ..Default::default() };
    println!("searching {} {} architectures with TPE...", trials,
             if ds { "DS_CNN" } else { "CNN" });
    let out = search(&cfg, &mut Surrogate).map_err(|e| anyhow::anyhow!(e))?;
    println!("\nPareto frontier (accuracy vs MFP_ops):");
    println!("{:>7} {:>9} {:>9}  architecture", "TOP-1", "MFP_ops", "size KB");
    for (desc, acc, mf, kb) in out.frontier_rows() {
        println!("{acc:6.1}% {mf:9.1} {kb:9.1}  {desc}");
    }
    let seed = KwsArch { ds, convs: vec![(3, 100); 6] };
    println!("\nseed for comparison: {:.1} MFP_ops, {:.1} KB",
             flops::mflops(&seed), flops::size_kb(&seed));
    for name in if ds { ["ds_kws1", "ds_kws3", "ds_kws9"] } else { ["kws1", "kws3", "kws9"] } {
        let a = paper_arch(name).unwrap();
        println!("paper {name}: {:.1} MFP_ops, {:.1} KB  [{}]",
                 flops::mflops(&a), flops::size_kb(&a), a.describe());
    }
    Ok(())
}
