//! Cascade serving quickstart: build a two-stage early-exit pipeline
//! (cheap gate → heavier classifier), register it in a `ModelRouter` as
//! ONE model, and serve a handful of requests through the dynamic
//! batcher. Early-exited requests come back with the gate's answer; the
//! rest run the downstream stage in its own input space. Per-stage
//! accounting (items in/out, exit rate, latency, arena checkouts) is the
//! same view `/metrics` serves under `cascade_stages`.
//!
//!     cargo run --example cascade_quickstart

use bonseyes::lne::platform::Platform;
use bonseyes::lne::quant_explore::f32_baseline;
use bonseyes::lne::{Graph, LayerKind, Padding, PoolKind, Prepared};
use bonseyes::models;
use bonseyes::serving::cascade::{Cascade, Gate, Stage, Transform};
use bonseyes::serving::{BatcherConfig, ModelRouter};
use bonseyes::tensor::Tensor;
use bonseyes::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut router = ModelRouter::with_threads(2);

    // stage 0 — "wake": a tiny binary gate; only items whose top-1
    // confidence stays below the threshold continue downstream
    let mut g = Graph::new("wake", (1, 12, 12));
    g.push("conv1", LayerKind::Conv { k: (3, 3), stride: (1, 1), pad: Padding::Same, relu_fused: true }, 4);
    g.push("gap", LayerKind::Pool { kind: PoolKind::Avg, k: 0, stride: 1, pad: 0, global: true }, 0);
    g.push("fc", LayerKind::Fc { relu_fused: false }, 2);
    g.push("prob", LayerKind::Softmax, 0);
    let w = models::random_weights(&g, 5);
    let gate_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let gate_a = f32_baseline(&gate_p);
    let wake_names: Vec<String> = vec!["quiet".into(), "wake".into()];
    let gate = Stage::lne(
        "wake",
        gate_p,
        gate_a,
        &[1, 8],
        &wake_names,
        Gate::ConfidenceBelow(0.75),
        Transform::identity(),
        &router.arena_pool,
        Arc::clone(&router.worker_pool),
    )
    .unwrap();

    // stage 1 — "command": the branchy inceptionette; the transform maps
    // the ORIGINAL 1x12x12 payload into its 3x16x16 input space
    let g = models::inceptionette::inceptionette();
    let w = models::random_weights(&g, 7);
    let cmd_p = Arc::new(Prepared::new(g, w, Platform::pi4()).unwrap());
    let cmd_a = f32_baseline(&cmd_p);
    let command = Stage::lne(
        "command",
        cmd_p,
        cmd_a,
        &[1, 8],
        &[],
        Gate::ConfidenceBelow(0.0), // final stage: gate unused
        Transform { resize: Some(((1, 12, 12), (3, 16, 16))), renormalize: true },
        &router.arena_pool,
        Arc::clone(&router.worker_pool),
    )
    .unwrap();

    let cascade = Cascade::new("wake-command").push(gate).unwrap().push(command).unwrap();
    router
        .register_cascade(cascade, BatcherConfig { max_wait_ms: 2.0, ..Default::default() })
        .unwrap();

    // serve a batch of requests through the router like any other model
    let mut rng = Rng::new(17);
    let tickets: Vec<_> = (0..8)
        .map(|_| {
            let x = Tensor::randn(&[1, 12, 12], 1.0, &mut rng).data;
            router.infer_async(Some("wake-command"), x).unwrap()
        })
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let p = t.wait().unwrap();
        let stage = if p.scores.len() == 2 { "wake (early exit)" } else { "command" };
        println!("request {i}: {:<10} from {stage:18} ({} scores)", p.class, p.scores.len());
    }

    // the same per-stage accounting /metrics serves under `cascade_stages`
    let snap = router.metrics.snapshot();
    if let Some(stages) = snap.get("cascade_stages").as_obj() {
        println!("\nper-stage accounting:");
        for (key, s) in stages {
            println!(
                "  {key:24} in {:3}  out {:3}  early-exit {:3} ({:4.0}%)  arenas {}",
                s.get("items_in").as_i64().unwrap_or(0),
                s.get("items_out").as_i64().unwrap_or(0),
                s.get("early_exits").as_i64().unwrap_or(0),
                s.get("exit_rate").as_f64().unwrap_or(0.0) * 100.0,
                s.get("arena_checkouts").as_i64().unwrap_or(0),
            );
        }
    }
    println!(
        "shared arena pool: {} arenas across both stages",
        router.arena_pool.arena_count()
    );
}
