"""L1 logmel kernel and the MFCC front-end vs the FFT-based oracle.

The oracle (kernels/ref.mfcc_ref) computes the power spectrum with
jnp.fft.rfft — a genuinely different algorithm from the kernel's
DFT-as-matmul — so agreement validates the TPU adaptation, not a copy."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import features
from compile.kernels import logmel as lk
from compile.kernels.ref import logmel_ref, mfcc_ref


def _toy_inputs(n, frame_len, f, n_mels, seed):
    rng = np.random.RandomState(seed)
    frames = jnp.asarray(rng.randn(n, frame_len), jnp.float32)
    cos_b = jnp.asarray(rng.randn(frame_len, f) * 0.1, jnp.float32)
    sin_b = jnp.asarray(rng.randn(frame_len, f) * 0.1, jnp.float32)
    mel_t = jnp.asarray(np.abs(rng.randn(f, n_mels)) * 0.05, jnp.float32)
    return frames, cos_b, sin_b, mel_t


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_logmel_kernel_matches_ref_fast(n, seed):
    frames, cos_b, sin_b, mel_t = _toy_inputs(n, 64, 32, 10, seed)
    got = lk.logmel(frames, cos_b, sin_b, mel_t)
    want = logmel_ref(frames, cos_b, sin_b, mel_t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 40), bn=st.sampled_from([4, 8, 16]),
       bf=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_logmel_kernel_matches_ref_tpu_grid(n, bn, bf, seed):
    """Multi-step frequency accumulation grid (the TPU VMEM schedule)."""
    frames, cos_b, sin_b, mel_t = _toy_inputs(n, 64, 32, 10, seed)
    got = lk.logmel(frames, cos_b, sin_b, mel_t, bn=bn, bf=bf)
    want = logmel_ref(frames, cos_b, sin_b, mel_t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(batch=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_mfcc_matches_fft_oracle(batch, seed):
    rng = np.random.RandomState(seed)
    audio = jnp.asarray(rng.randn(batch, features.SAMPLE_RATE) * 0.1,
                        jnp.float32)
    got = features.mfcc(audio)
    want = mfcc_ref(audio)
    assert got.shape == (batch, features.N_MELS, features.N_FRAMES)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_mfcc_output_is_paper_shape():
    audio = jnp.zeros((2, 16000), jnp.float32)
    assert features.mfcc(audio).shape == (2, 40, 32)  # paper: 40x32 tensor


def test_mel_filterbank_properties():
    fb = features.mel_filterbank()
    assert fb.shape == (features.N_MELS, features.N_FREQ)
    assert np.all(fb >= 0)
    assert np.all(fb.sum(axis=1) > 0), "every filter must have support"
    # Triangles are ordered: center bins strictly increase.
    centers = fb.argmax(axis=1)
    assert np.all(np.diff(centers) > 0)


def test_dct_matrix_orthonormal():
    d = features.dct_matrix()
    np.testing.assert_allclose(d @ d.T, np.eye(features.N_MELS), atol=1e-5)


def test_dft_bases_match_rfft():
    cos_b, sin_b = features.dft_bases()
    rng = np.random.RandomState(0)
    x = rng.randn(3, features.FRAME_LEN).astype(np.float32)
    w = features.hann(features.FRAME_LEN)
    want = np.fft.rfft(x * w, axis=-1)
    got_re = x @ cos_b[:, :features.N_FREQ]
    got_im = x @ sin_b[:, :features.N_FREQ]
    np.testing.assert_allclose(got_re, want.real, atol=2e-2)
    np.testing.assert_allclose(got_im, want.imag, atol=2e-2)
    # padding region is exactly zero contribution
    fb = features.constants()[2]
    assert np.all(fb[features.N_FREQ:] == 0)


def test_vmem_estimate_fits_budget():
    assert 2 * lk.vmem_bytes() < 16 * 1024 * 1024
