"""L1 matmul kernel vs pure-jnp oracle: hypothesis sweep over shapes, both
tiling policies (single-step fast-interp blocks and the multi-step TPU grid),
plus custom_vjp gradient checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (matmul_bias_act, matmul_bias_act_raw,
                                    vmem_bytes)
from compile.kernels.ref import matmul_bias_act_ref


def rnd(rng, *shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 90), k=st.integers(1, 70), n=st.integers(1, 50),
       act=st.sampled_from(["none", "relu"]), seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_fast_tiling(m, k, n, act, seed):
    rng = np.random.RandomState(seed)
    x, w, b = rnd(rng, m, k), rnd(rng, k, n), rnd(rng, n)
    got = matmul_bias_act_raw(x, w, b, act)
    want = matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 40),
       bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]),
       bn=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_tpu_grid(m, k, n, bm, bk, bn, seed):
    """Multi-step (M, N, K) grid with K-axis accumulation (the TPU schedule)."""
    rng = np.random.RandomState(seed)
    x, w, b = rnd(rng, m, k), rnd(rng, k, n), rnd(rng, n)
    got = matmul_bias_act_raw(x, w, b, "relu", bm=bm, bk=bk, bn=bn)
    want = matmul_bias_act_ref(x, w, b, "relu")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("act", ["none", "relu"])
def test_vjp_matches_ref_grads(act):
    rng = np.random.RandomState(0)
    x, w, b = rnd(rng, 17, 23), rnd(rng, 23, 9), rnd(rng, 9)

    def f(x, w, b):
        return (matmul_bias_act(x, w, b, act) * jnp.cos(
            jnp.arange(17 * 9, dtype=jnp.float32).reshape(17, 9))).sum()

    def fr(x, w, b):
        return (matmul_bias_act_ref(x, w, b, act) * jnp.cos(
            jnp.arange(17 * 9, dtype=jnp.float32).reshape(17, 9))).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_vjp_relu_masks_gradient():
    x = jnp.asarray([[-5.0, 5.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    g = jax.grad(lambda x: matmul_bias_act(x, w, b, "relu").sum())(x)
    np.testing.assert_allclose(g, [[0.0, 1.0]])


def test_jit_compiles():
    rng = np.random.RandomState(1)
    x, w, b = rnd(rng, 33, 65), rnd(rng, 65, 12), rnd(rng, 12)
    got = jax.jit(lambda x, w, b: matmul_bias_act(x, w, b, "none"))(x, w, b)
    np.testing.assert_allclose(got, matmul_bias_act_ref(x, w, b), rtol=1e-5,
                               atol=1e-4)


def test_vmem_estimate_fits_budget():
    # The documented TPU tiling must fit a 16 MB VMEM with double buffering.
    assert 2 * vmem_bytes() < 16 * 1024 * 1024
