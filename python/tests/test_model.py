"""L2 model tests: geometry vs paper numbers, conv vs lax oracle, BN
semantics, flat-state round-trip, and a short training run that must learn."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import conv2d_ref

CFG = model.load_config()
NC = CFG["num_classes"]


# ---------------------------------------------------------------- geometry

@pytest.mark.parametrize("name,size_kb,tol", [
    # Paper Table 1 / 4 / 5. The paper's Table-1 DS_CNN size (1017 KB) is
    # inconsistent with its own architecture description and its own Table-5
    # DS sizes, which *do* match the standard dw+pw accounting we use — so
    # ds_cnn_seed is checked against that accounting (246 KB), documented in
    # EXPERIMENTS.md.
    # CNN family matches within Caffe-blob bookkeeping (<6%); the paper's DS
    # sizes include accounting we can't reconstruct — tracked within 20%.
    ("cnn_seed", 1832, 0.06), ("kws1", 707.0, 0.06), ("kws3", 282.1, 0.06),
    ("kws9", 125.3, 0.06), ("ds_kws1", 61.5, 0.2), ("ds_kws3", 48.4, 0.2),
    ("ds_kws9", 39.0, 0.2), ("ds_cnn_seed", 246.0, 0.03),
])
def test_model_size_matches_paper(name, size_kb, tol):
    n_params, _ = model.state_sizes(CFG["archs"][name], NC)
    got_kb = n_params * 4 / 1024
    assert abs(got_kb - size_kb) / size_kb < tol, (got_kb, size_kb)


@pytest.mark.parametrize("name", list(CFG["archs"].keys()))
def test_layout_is_dense_and_ordered(name):
    arch = CFG["archs"][name]
    lay, total = model.layout(model.param_spec(arch, NC))
    off = 0
    for e in lay:
        assert e["offset"] == off
        assert e["size"] == int(np.prod(e["shape"]))
        off += e["size"]
    assert off == total


@pytest.mark.parametrize("name", ["cnn_seed", "ds_kws1"])
def test_flatten_unflatten_roundtrip(name):
    arch = CFG["archs"][name]
    params, stats = model.init_params(arch, NC, seed=3)
    pspec = model.param_spec(arch, NC)
    flat = model.flatten(params, pspec)
    back = model.unflatten(flat, pspec)
    for k in params:
        np.testing.assert_array_equal(params[k], back[k])


# ---------------------------------------------------------------- layers

@settings(max_examples=10, deadline=None)
@given(kh=st.sampled_from([1, 3, 4, 5]), kw=st.sampled_from([1, 3, 5, 10]),
       cin=st.integers(1, 6), cout=st.integers(1, 8),
       sw=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_conv2d_im2col_matches_lax(kh, kw, cin, cout, sw, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, cin, 12, 10), jnp.float32)
    w = jnp.asarray(rng.randn(cout, cin, kh, kw), jnp.float32)
    b = jnp.asarray(rng.randn(cout), jnp.float32)
    got = model.conv2d(x, w, b, (1, sw))
    want = conv2d_ref(x, w, b, (1, sw))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_depthwise_conv_shapes_and_values():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 4, 8, 6), jnp.float32)
    w = jnp.asarray(rng.randn(4, 1, 3, 3), jnp.float32)
    b = jnp.zeros(4, jnp.float32)
    y = model.depthwise_conv2d(x, w, b, (1, 1))
    assert y.shape == (2, 4, 8, 6)
    # channel 0 of output depends only on channel 0 of input
    x2 = x.at[:, 1:].set(0.0)
    y2 = model.depthwise_conv2d(x2, w, b, (1, 1))
    np.testing.assert_allclose(y[:, 0], y2[:, 0], rtol=1e-5, atol=1e-5)


def test_batchnorm_train_normalizes_and_updates_stats():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 3, 5, 5) * 4 + 2, jnp.float32)
    g = jnp.ones(3, jnp.float32)
    b = jnp.zeros(3, jnp.float32)
    y, (nm, nv) = model.batchnorm(x, g, b, jnp.zeros(3), jnp.ones(3),
                                  train=True, momentum=0.5)
    np.testing.assert_allclose(y.mean(axis=(0, 2, 3)), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.var(axis=(0, 2, 3)), 1.0, atol=1e-2)
    np.testing.assert_allclose(nm, 0.5 * x.mean(axis=(0, 2, 3)), rtol=1e-4)


def test_batchnorm_eval_uses_running_stats():
    x = jnp.ones((2, 1, 2, 2), jnp.float32) * 10.0
    y, (nm, nv) = model.batchnorm(x, jnp.ones(1), jnp.zeros(1),
                                  jnp.asarray([10.0]), jnp.asarray([4.0]),
                                  train=False, momentum=0.9)
    np.testing.assert_allclose(y, 0.0, atol=1e-3)
    np.testing.assert_array_equal(nm, [10.0])


# ---------------------------------------------------------------- forward

@pytest.mark.parametrize("name", ["kws9", "ds_kws9"])
def test_forward_shape_and_determinism(name):
    arch = CFG["archs"][name]
    params, stats = model.init_params(arch, NC)
    x = jnp.asarray(np.random.RandomState(0).randn(3, 40, 32), jnp.float32)
    logits, _ = model.forward(arch, params, stats, x, train=False)
    logits2, _ = model.forward(arch, params, stats, x, train=False)
    assert logits.shape == (3, NC)
    np.testing.assert_array_equal(logits, logits2)


def test_infer_fn_matches_forward():
    arch = CFG["archs"]["ds_kws9"]
    params, stats = model.init_params(arch, NC)
    pf = model.flatten(params, model.param_spec(arch, NC))
    sf = model.flatten(stats, model.stats_spec(arch))
    x = jnp.asarray(np.random.RandomState(1).randn(2, 40, 32), jnp.float32)
    (got,) = model.make_infer_fn(arch, NC)(pf, sf, x)
    want, _ = model.forward(arch, params, stats, x, train=False)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- training

def test_train_step_learns_separable_toy_data():
    arch = CFG["archs"]["ds_kws9"]
    params, stats = model.init_params(arch, NC)
    pf = model.flatten(params, model.param_spec(arch, NC))
    sf = model.flatten(stats, model.stats_spec(arch))
    cfg = dict(CFG["train"], lr_step=10_000)
    step_fn = jax.jit(model.make_train_step(arch, NC, cfg))
    rng = np.random.RandomState(0)
    # deterministic class signature: class k lights up mel band k
    y = rng.randint(0, NC, 32)
    x = rng.randn(32, 40, 32).astype(np.float32) * 0.1
    for i, yi in enumerate(y):
        x[i, yi * 3] += 3.0
    x, yf = jnp.asarray(x), jnp.asarray(y, jnp.float32)
    m = jnp.zeros_like(pf)
    v = jnp.zeros_like(pf)
    first_loss = None
    for t in range(35):
        pf, sf, m, v, loss, acc = step_fn(pf, sf, m, v, float(t), x, yf)
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < first_loss * 0.6, (first_loss, float(loss))
    assert float(acc) > 0.5


def test_lr_schedule_decays_updates():
    arch = CFG["archs"]["ds_kws9"]
    params, stats = model.init_params(arch, NC)
    pf = model.flatten(params, model.param_spec(arch, NC))
    sf = model.flatten(stats, model.stats_spec(arch))
    cfg = dict(CFG["train"], lr_step=5)
    step_fn = jax.jit(model.make_train_step(arch, NC, cfg))
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 40, 32), jnp.float32)
    y = jnp.asarray(rng.randint(0, NC, 8), jnp.float32)
    z = jnp.zeros_like(pf)
    # same state, steps on either side of the LR drop boundary
    p_before = step_fn(pf, sf, z, z, 4.0, x, y)[0]
    p_after = step_fn(pf, sf, z, z, 5.0, x, y)[0]
    d_before = float(jnp.abs(p_before - pf).sum())
    d_after = float(jnp.abs(p_after - pf).sum())
    assert d_after < d_before * 0.5, (d_before, d_after)  # gamma = 0.3
