"""AOT artifact tests: manifest consistency, HLO-text validity, init blobs."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model, features

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "artifacts"))
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts not built (run `make artifacts`)")


def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_all_archs_and_graphs():
    m = manifest()
    cfg = model.load_config()
    assert set(m["archs"].keys()) == set(cfg["archs"].keys())
    names = {g["name"] for g in m["graphs"]}
    for b in aot.MFCC_BATCHES:
        assert f"mfcc_b{b}" in names
    for a in cfg["archs"]:
        for b in cfg["infer_batches"]:
            assert f"{a}_infer_b{b}" in names
        assert f"{a}_train_b{m['train_cfg']['batch']}" in names


def test_all_graph_files_exist_and_are_hlo_text():
    m = manifest()
    for g in m["graphs"]:
        path = os.path.join(ART, g["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, path


def test_layouts_match_model_spec():
    m = manifest()
    cfg = model.load_config()
    for name, entry in m["archs"].items():
        arch = cfg["archs"][name]
        lay, total = model.layout(model.param_spec(arch, m["num_classes"]))
        assert entry["n_params"] == total
        assert entry["param_layout"] == lay
        slay, stotal = model.layout(model.stats_spec(arch))
        assert entry["n_stats"] == stotal


def test_init_blobs_have_layout_size():
    m = manifest()
    for name, entry in m["archs"].items():
        blob = np.fromfile(os.path.join(ART, entry["init_file"]), "<f4")
        assert blob.shape[0] == entry["n_params"]
        stats = np.fromfile(os.path.join(ART, entry["init_stats_file"]), "<f4")
        assert stats.shape[0] == entry["n_stats"]
        # BN variances init to 1, means to 0
        for e in entry["stats_layout"]:
            seg = stats[e["offset"]:e["offset"] + e["size"]]
            if e["name"].endswith("_var"):
                np.testing.assert_array_equal(seg, 1.0)
            else:
                np.testing.assert_array_equal(seg, 0.0)


def test_graph_io_shapes_are_consistent():
    m = manifest()
    for g in m["graphs"]:
        if g["kind"] == "mfcc":
            assert g["inputs"][0]["shape"] == [g["batch"], m["samples"]]
            assert g["outputs"][0]["shape"] == [g["batch"], m["mel_bands"],
                                                m["frames"]]
        elif g["kind"] == "infer":
            arch = m["archs"][g["arch"]]
            assert g["inputs"][0]["shape"] == [arch["n_params"]]
            assert g["outputs"][0]["shape"] == [g["batch"], m["num_classes"]]
        elif g["kind"] == "train":
            arch = m["archs"][g["arch"]]
            assert [i["name"] for i in g["inputs"]] == \
                ["params", "stats", "m", "v", "step", "x", "y"]
            assert g["outputs"][4]["name"] == "loss"


def test_nas_mode_emits_candidate(tmp_path):
    arch_json = json.dumps(
        {"type": "cnn", "convs": [{"k": [3, 3], "c": 4}] * 2})
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--arch-json", arch_json,
         "--name", "cand_t", "--out-dir", str(tmp_path),
         "--infer-batches", "4", "--train-batch", "4"],
        check=True, cwd=os.path.join(os.path.dirname(__file__), ".."))
    with open(tmp_path / "cand_t.manifest.json") as f:
        mm = json.load(f)
    assert "cand_t" in mm["archs"]
    assert (tmp_path / "cand_t_infer_b4.hlo.txt").exists()
    assert (tmp_path / "cand_t_train_b4.hlo.txt").exists()
