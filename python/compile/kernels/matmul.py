"""L1 Pallas kernel: tiled matmul with fused bias + activation.

This is the compute hot-spot of the KWS models: every convolution lowers to
im2col + this kernel, and the FC head calls it directly, so the whole model
inference is dominated by MXU-shaped matmul tiles.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): instead of Arm NEON
microkernels the paper's LNE plugins use, the kernel expresses an
HBM->VMEM schedule with a (M/bm, N/bn, K/bk) grid; the K axis is the
innermost (sequential/reduction) grid dimension accumulating into the
output block, which stays resident in VMEM across K steps.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the AOT artifact runs
on the rust PJRT CPU client. Real-TPU perf is estimated in DESIGN.md §Perf.

A `jax.custom_vjp` wrapper makes the kernel differentiable (pallas_call has
no automatic transpose rule); the backward pass reuses the same kernel for
dX = dZ @ W^T and dW = X^T @ dZ, so training lowers through L1 too.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU VMEM tile sizes. 128 matches the MXU systolic-array edge; 512 on K
# amortizes the accumulate loop. Tiles are clamped to the (padded) problem.
BM, BK, BN = 128, 512, 128

# Tiling policy. On a real TPU the (BM, BK, BN) grid above is the point of
# the kernel: the K axis streams HBM->VMEM while the output tile stays
# resident. Under interpret=True every grid step is a sequential
# dynamic-slice loop iteration in the lowered HLO, so the same tiling that
# is optimal on the MXU is pure overhead on the CPU PJRT backend (measured
# ~85x on a 20480x360x30 matmul; see EXPERIMENTS.md §Perf). AOT artifacts
# therefore lower with `fast_interp` single-step blocks; tests exercise the
# multi-step TPU grid for correctness with small explicit tiles.
FAST_INTERP = True


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, act: str):
    """One (bm, bn) output tile; grid dim 2 walks K and accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        r = o_ref[...] + b_ref[...]
        if act == "relu":
            r = jnp.maximum(r, 0.0)
        o_ref[...] = r


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def matmul_bias_act_raw(x, w, b, act: str = "none", bm: int = 0, bk: int = 0, bn: int = 0):
    """out = act(x @ w + b); x:[M,K] w:[K,N] b:[N]. Pure pallas, no vjp.

    bm/bk/bn = 0 selects the policy default: whole-array single-step blocks
    under FAST_INTERP (CPU artifacts), MXU tiles otherwise.
    """
    assert x.ndim == 2 and w.ndim == 2 and b.ndim == 1
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape[0] == n, (x.shape, w.shape, b.shape)
    if bm == 0:
        bm, bk, bn = (m, k, n) if FAST_INTERP else (BM, BK, BN)
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    xp = _pad_to(x.astype(jnp.float32), (bm_, bk_))
    wp = _pad_to(w.astype(jnp.float32), (bk_, bn_))
    bp = _pad_to(b.astype(jnp.float32), (bn_,)).reshape(1, -1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=grid[2], act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(x, w, b, act: str = "none"):
    """Differentiable act(x @ w + b) routed through the L1 pallas kernel."""
    return matmul_bias_act_raw(x, w, b, act)


def _mm_fwd(x, w, b, act):
    y = matmul_bias_act_raw(x, w, b, act)
    return y, (x, w, y)


def _mm_bwd(act, res, dy):
    x, w, y = res
    dz = jnp.where(y > 0, dy, 0.0) if act == "relu" else dy
    zeros_k = jnp.zeros((w.shape[0],), jnp.float32)
    zeros_n = jnp.zeros((w.shape[1],), jnp.float32)
    dx = matmul_bias_act_raw(dz, w.T, zeros_k, "none")
    dw = matmul_bias_act_raw(x.T, dz, zeros_n, "none")
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_mm_fwd, _mm_bwd)


def vmem_bytes(bm: int = BM, bk: int = BK, bn: int = BN) -> int:
    """Estimated VMEM residency of one grid step (f32): x, w, bias, acc tiles."""
    return 4 * (bm * bk + bk * bn + bn + bm * bn)
