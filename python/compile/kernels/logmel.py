"""L1 Pallas kernel: fused log-mel spectrogram (the MFCC hot path, paper §4).

The paper extracts MFCC features with librosa (FFT-based) on the ingestion
host. TPU adaptation (DESIGN.md §Hardware-Adaptation): an FFT butterfly is
hostile to a systolic array, so the DFT is expressed as two matmuls against
fixed cos/sin bases with the Hann window folded into the bases:

    power[f] = (x @ Cw)[f]^2 + (x @ Sw)[f]^2      Cw[t,f] = w[t] cos(2pi t f / N)

followed in the same kernel by the mel projection and log:

    out = log(power @ MelT + eps)

VMEM schedule: the full bases are f32[2048, F] (~9 MB each, too big together
with the frame block), so the grid is (frame_blocks, freq_blocks) and the
frequency axis is the sequential/accumulation dimension: each step computes a
(bn, bf) power tile and accumulates its mel projection into the resident
(bn, n_mels) output block; the final step applies the log. Frequency rows
>= n_freq (padding) carry all-zero mel columns, so they contribute nothing.

interpret=True for CPU-PJRT execution (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_FRAMES = 32   # frame-block rows per grid step (TPU tiling)
BF = 128         # frequency-tile width (sequential axis, TPU tiling)

# Same policy as kernels/matmul.py: the (BN_FRAMES, BF) grid is the TPU VMEM
# schedule; under interpret=True each grid step is a sequential loop, so CPU
# artifacts lower with whole-array single-step blocks.
FAST_INTERP = True


def _logmel_kernel(x_ref, c_ref, s_ref, m_ref, o_ref, *, f_steps: int, eps: float):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xc = jnp.dot(x_ref[...], c_ref[...], preferred_element_type=jnp.float32)
    xs = jnp.dot(x_ref[...], s_ref[...], preferred_element_type=jnp.float32)
    power = xc * xc + xs * xs
    o_ref[...] += jnp.dot(power, m_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == f_steps - 1)
    def _epilogue():
        o_ref[...] = jnp.log(o_ref[...] + eps)


def logmel(frames, cos_basis, sin_basis, mel_t, eps: float = 1e-6,
           bn: int = 0, bf: int = 0):
    """log(((frames@C)^2 + (frames@S)^2) @ mel_t + eps), fused in one kernel.

    frames:    f32[N, frame_len]   windowless frames (window folded in bases)
    cos_basis: f32[frame_len, F]   F padded to a multiple of `bf`
    sin_basis: f32[frame_len, F]
    mel_t:     f32[F, n_mels]      rows >= n_freq must be zero
    returns    f32[N, n_mels]
    """
    n, frame_len = frames.shape
    f = cos_basis.shape[1]
    if bn == 0:
        bn, bf = (n, f) if FAST_INTERP else (BN_FRAMES, BF)
    n_mels = mel_t.shape[1]
    assert cos_basis.shape == sin_basis.shape == (frame_len, f)
    assert mel_t.shape[0] == f
    bn_ = min(bn, n)
    pad_n = (-n) % bn_
    fp = frames if pad_n == 0 else jnp.pad(frames, ((0, pad_n), (0, 0)))
    assert f % bf == 0, f"freq axis {f} must be a multiple of bf={bf}"
    grid = (fp.shape[0] // bn_, f // bf)
    out = pl.pallas_call(
        functools.partial(_logmel_kernel, f_steps=grid[1], eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn_, frame_len), lambda i, ff: (i, 0)),
            pl.BlockSpec((frame_len, bf), lambda i, ff: (0, ff)),
            pl.BlockSpec((frame_len, bf), lambda i, ff: (0, ff)),
            pl.BlockSpec((bf, n_mels), lambda i, ff: (ff, 0)),
        ],
        out_specs=pl.BlockSpec((bn_, n_mels), lambda i, ff: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((fp.shape[0], n_mels), jnp.float32),
        interpret=True,
    )(fp, cos_basis, sin_basis, mel_t)
    return out[:n]


def vmem_bytes(frame_len: int = 2048, n_mels: int = 40,
               bn: int = BN_FRAMES, bf: int = BF) -> int:
    """Estimated VMEM residency of one grid step (f32)."""
    return 4 * (bn * frame_len + 2 * frame_len * bf + bf * n_mels + bn * n_mels)
