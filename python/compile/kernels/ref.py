"""Pure-jnp oracles for the L1 pallas kernels (pytest compares against these).

Nothing here touches pallas; these are the ground-truth definitions of the
computations the kernels implement. The MFCC oracle uses jnp.fft.rfft (the
"librosa path" the paper used) so the DFT-as-matmul adaptation is validated
against a genuinely different algorithm, not against itself.
"""

import numpy as np
import jax.numpy as jnp


def matmul_bias_act_ref(x, w, b, act: str = "none"):
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    return y


def logmel_ref(frames, cos_basis, sin_basis, mel_t, eps: float = 1e-6):
    """Same math as the kernel, plain jnp (used for exact-path comparison)."""
    xc = frames @ cos_basis
    xs = frames @ sin_basis
    power = xc * xc + xs * xs
    return jnp.log(power @ mel_t + eps)


def mfcc_ref(audio):
    """FFT-based MFCC oracle: frame -> hann -> rfft power -> mel -> log -> DCT."""
    from .. import features as ft

    padded = jnp.pad(audio, ((0, 0), (ft.FRAME_LEN // 2, ft.FRAME_LEN // 2)))
    idx = (np.arange(ft.N_FRAMES)[:, None] * ft.STRIDE
           + np.arange(ft.FRAME_LEN)[None, :])
    frames = padded[:, idx]                                  # [B, 32, 2048]
    windowed = frames * jnp.asarray(ft.hann(ft.FRAME_LEN), jnp.float32)
    spec = jnp.fft.rfft(windowed, axis=-1)                   # [B, 32, 1025]
    power = jnp.abs(spec) ** 2
    fb = jnp.asarray(ft.mel_filterbank())                    # [40, 1025]
    mel = power @ fb.T                                       # [B, 32, 40]
    logmel = jnp.log(mel + ft.LOG_EPS)
    coeffs = logmel @ jnp.asarray(ft.dct_matrix()).T         # [B, 32, 40]
    return coeffs.transpose(0, 2, 1).astype(jnp.float32)     # [B, 40, 32]


def conv2d_ref(x, w, b, stride=(1, 1)):
    """SAME-padded NCHW conv oracle via jax.lax (used by model tests)."""
    import jax

    y = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b.reshape(1, -1, 1, 1)
