"""MFCC front-end (paper §4): framing + window + DFT + mel + log + DCT.

Constants (bases, filterbank, DCT matrix) are built with numpy at trace time
and baked into the HLO artifact; the per-request compute is the L1 pallas
kernel (kernels/logmel.py) plus one DCT matmul.

Paper parameters: 16 kHz audio, 128 ms frames (2048 samples), 32 ms stride
(512 samples), 40 mel bands, 40x32 MFCC output per 1 s sample. Center
padding (frame_len/2 on both sides, librosa-style) yields exactly 32 frames.
"""

import functools

import numpy as np
import jax.numpy as jnp

from .kernels import logmel as logmel_kernel

SAMPLE_RATE = 16000
FRAME_LEN = 2048
STRIDE = 512
N_MELS = 40
N_FRAMES = 32
N_FREQ = FRAME_LEN // 2 + 1            # 1025 one-sided bins
F_PAD = -(-N_FREQ // logmel_kernel.BF) * logmel_kernel.BF  # padded to 1152
LOG_EPS = 1e-6


def hann(n: int) -> np.ndarray:
    return 0.5 - 0.5 * np.cos(2.0 * np.pi * np.arange(n) / n)


def dft_bases(frame_len: int = FRAME_LEN, f_pad: int = F_PAD):
    """Windowed one-sided DFT bases: Cw[t,f] = hann[t] cos(2pi t f / N)."""
    t = np.arange(frame_len)[:, None]
    f = np.arange(f_pad)[None, :]
    ang = 2.0 * np.pi * t * f / frame_len
    w = hann(frame_len)[:, None]
    cos_b = (w * np.cos(ang)).astype(np.float32)
    sin_b = (w * -np.sin(ang)).astype(np.float32)
    return cos_b, sin_b


def hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)


def mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)


def mel_filterbank(n_mels: int = N_MELS, n_freq: int = N_FREQ,
                   sample_rate: int = SAMPLE_RATE, fmin: float = 20.0,
                   fmax: float = None) -> np.ndarray:
    """HTK-style triangular mel filterbank, shape [n_mels, n_freq]."""
    fmax = fmax or sample_rate / 2.0
    mel_pts = np.linspace(hz_to_mel(fmin), hz_to_mel(fmax), n_mels + 2)
    hz_pts = mel_to_hz(mel_pts)
    bins = np.floor((FRAME_LEN + 1) * hz_pts / sample_rate).astype(int)
    fb = np.zeros((n_mels, n_freq), dtype=np.float32)
    for m in range(1, n_mels + 1):
        lo, ctr, hi = bins[m - 1], bins[m], bins[m + 1]
        ctr = max(ctr, lo + 1)
        hi = max(hi, ctr + 1)
        for k in range(lo, min(ctr, n_freq)):
            fb[m - 1, k] = (k - lo) / (ctr - lo)
        for k in range(ctr, min(hi, n_freq)):
            fb[m - 1, k] = (hi - k) / (hi - ctr)
    return fb


def dct_matrix(n: int = N_MELS) -> np.ndarray:
    """Orthonormal DCT-II matrix, shape [n, n]; row k = k-th coefficient."""
    k = np.arange(n)[:, None]
    t = np.arange(n)[None, :]
    d = np.sqrt(2.0 / n) * np.cos(np.pi * (t + 0.5) * k / n)
    d[0] *= np.sqrt(0.5)
    return d.astype(np.float32)


@functools.lru_cache(maxsize=1)
def constants():
    """(cos_basis, sin_basis, mel_t_padded, dct_t) as numpy arrays."""
    cos_b, sin_b = dft_bases()
    fb = mel_filterbank()                      # [40, 1025]
    mel_t = np.zeros((F_PAD, N_MELS), dtype=np.float32)
    mel_t[:N_FREQ, :] = fb.T                   # padded rows stay zero
    dct_t = dct_matrix().T                     # [40, 40], logmel @ dct_t
    return cos_b, sin_b, mel_t, dct_t


def frame_signal(audio):
    """audio f32[B, samples] -> centered frames f32[B*N_FRAMES, FRAME_LEN]."""
    b = audio.shape[0]
    padded = jnp.pad(audio, ((0, 0), (FRAME_LEN // 2, FRAME_LEN // 2)))
    idx = np.arange(N_FRAMES)[:, None] * STRIDE + np.arange(FRAME_LEN)[None, :]
    frames = padded[:, idx]                    # [B, 32, 2048] gather
    return frames.reshape(b * N_FRAMES, FRAME_LEN)


def mfcc(audio):
    """f32[B, 16000] -> f32[B, N_MELS, N_FRAMES] MFCC tensor (paper's 40x32)."""
    b = audio.shape[0]
    cos_b, sin_b, mel_t, dct_t = constants()
    frames = frame_signal(audio)
    lm = logmel_kernel.logmel(frames, jnp.asarray(cos_b), jnp.asarray(sin_b),
                              jnp.asarray(mel_t), eps=LOG_EPS)
    coeffs = lm @ jnp.asarray(dct_t)           # [B*32, 40]
    return coeffs.reshape(b, N_FRAMES, N_MELS).transpose(0, 2, 1)
