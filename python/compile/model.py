"""L2: the paper's KWS model family (Tables 1, 4, 5) in JAX, calling L1 kernels.

Two families (paper §5.2):
  - `cnn`:    6x [conv -> batchnorm -> scale -> ReLU], avg-pool, FC.
  - `ds_cnn`: conv block, then 5x depthwise-separable blocks
              (dw conv -> BN -> ReLU -> pw conv -> BN -> ReLU), avg-pool, FC.

Geometry: conv1 stride (1,2), all later convs stride (1,1), SAME padding —
this reproduces the paper's reported MFP_ops and model sizes exactly (see
configs/kws_archs.json).

Every standard/pointwise convolution lowers through im2col + the L1 pallas
matmul kernel (kernels/matmul.py), as does the FC head, so the model's
compute hot-spot is the L1 kernel in both the inference and training HLO.
Depthwise convolutions use lax.conv with feature_group_count (im2col
degenerates per-channel; XLA's native dw conv is the right lowering).

State layout: parameters / BN running stats / Adam moments are exchanged with
the rust coordinator as *flat f32 vectors* with an explicit (name, kind,
offset, shape) layout table recorded in the artifact manifest, so the rust
tools (quantize, sparsify, checkpointing) can address individual tensors.

Training step (paper §5.1): multinomial logistic loss + Adam, multi-step LR
(lr = base * gamma^floor(step/lr_step)), BN batch stats with running-average
update. Signature (all f32):
    (params[P], stats[S], m[P], v[P], step[], x[B,40,32], y[B])
 -> (params'[P], stats'[S], m'[P], v'[P], loss[], acc[])
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.matmul import matmul_bias_act

BN_EPS = 1e-5
CONFIG_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "configs",
                           "kws_archs.json")


def load_config(path: str = CONFIG_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


# --------------------------------------------------------------------------
# Parameter / stats specs (ordered; flat-vector layout derives from these)
# --------------------------------------------------------------------------

def param_spec(arch: dict, num_classes: int):
    """Ordered trainable-parameter spec: list of (name, shape, kind)."""
    spec = []
    c_in = 1
    for i, conv in enumerate(arch["convs"]):
        kh, kw = conv["k"]
        c = conv["c"]
        n = i + 1
        if arch["type"] == "cnn" or i == 0:
            spec.append((f"conv{n}_w", (c, c_in, kh, kw), "conv_w"))
            spec.append((f"conv{n}_b", (c,), "bias"))
            spec.append((f"bn{n}_gamma", (c,), "bn_gamma"))
            spec.append((f"bn{n}_beta", (c,), "bn_beta"))
        else:
            spec.append((f"dw{n}_w", (c_in, 1, kh, kw), "dw_w"))
            spec.append((f"dw{n}_b", (c_in,), "bias"))
            spec.append((f"bn{n}d_gamma", (c_in,), "bn_gamma"))
            spec.append((f"bn{n}d_beta", (c_in,), "bn_beta"))
            spec.append((f"pw{n}_w", (c, c_in, 1, 1), "conv_w"))
            spec.append((f"pw{n}_b", (c,), "bias"))
            spec.append((f"bn{n}p_gamma", (c,), "bn_gamma"))
            spec.append((f"bn{n}p_beta", (c,), "bn_beta"))
        c_in = c
    spec.append(("fc_w", (c_in, num_classes), "fc_w"))
    spec.append(("fc_b", (num_classes,), "bias"))
    return spec


def stats_spec(arch: dict):
    """Ordered BN running-stat spec: list of (name, shape)."""
    spec = []
    c_in = 1
    for i, conv in enumerate(arch["convs"]):
        c = conv["c"]
        n = i + 1
        if arch["type"] == "cnn" or i == 0:
            spec.append((f"bn{n}_mean", (c,)))
            spec.append((f"bn{n}_var", (c,)))
        else:
            spec.append((f"bn{n}d_mean", (c_in,)))
            spec.append((f"bn{n}d_var", (c_in,)))
            spec.append((f"bn{n}p_mean", (c,)))
            spec.append((f"bn{n}p_var", (c,)))
        c_in = c
    return spec


def layout(spec):
    """[(name, kind, offset, shape)] plus total length, for the manifest."""
    out, off = [], 0
    for entry in spec:
        name, shape = entry[0], entry[1]
        kind = entry[2] if len(entry) > 2 else "stat"
        size = int(np.prod(shape))
        out.append({"name": name, "kind": kind, "offset": off,
                    "shape": list(shape), "size": size})
        off += size
    return out, off


def flatten(tree: dict, spec) -> jnp.ndarray:
    return jnp.concatenate([tree[e[0]].reshape(-1) for e in spec]) \
        if spec else jnp.zeros((0,), jnp.float32)


def unflatten(flat: jnp.ndarray, spec) -> dict:
    out, off = {}, 0
    for entry in spec:
        name, shape = entry[0], entry[1]
        size = int(np.prod(shape))
        out[name] = flat[off:off + size].reshape(shape)
        off += size
    return out


def init_params(arch: dict, num_classes: int, seed: int = 0):
    """He-init conv/fc weights; returns (params_dict, stats_dict)."""
    rng = np.random.RandomState(seed)
    params, stats = {}, {}
    for name, shape, kind in param_spec(arch, num_classes):
        if kind in ("conv_w", "dw_w"):
            fan_in = int(np.prod(shape[1:]))
            params[name] = jnp.asarray(
                rng.randn(*shape) * np.sqrt(2.0 / fan_in), jnp.float32)
        elif kind == "fc_w":
            fan_in = shape[0]
            params[name] = jnp.asarray(
                rng.randn(*shape) * np.sqrt(1.0 / fan_in), jnp.float32)
        elif kind == "bn_gamma":
            params[name] = jnp.ones(shape, jnp.float32)
        else:  # bias, bn_beta
            params[name] = jnp.zeros(shape, jnp.float32)
    for name, shape in stats_spec(arch):
        stats[name] = (jnp.zeros if name.endswith("_mean") else jnp.ones)(
            shape, jnp.float32)
    return params, stats


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def conv2d(x, w, b, stride):
    """SAME conv NCHW via im2col + the L1 pallas matmul kernel."""
    bsz, c_in, h, wd = x.shape
    c_out, _, kh, kw = w.shape
    if (kh, kw) == (1, 1) and stride == (1, 1):
        flat = x.transpose(0, 2, 3, 1).reshape(-1, c_in)
        y = matmul_bias_act(flat, w.reshape(c_out, c_in).T, b, "none")
        return y.reshape(bsz, h, wd, c_out).transpose(0, 3, 1, 2)
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=stride, padding="SAME")
    _, feat, ho, wo = patches.shape            # feat = c_in*kh*kw, (c, kh, kw)
    flat = patches.transpose(0, 2, 3, 1).reshape(-1, feat)
    y = matmul_bias_act(flat, w.reshape(c_out, feat).T, b, "none")
    return y.reshape(bsz, ho, wo, c_out).transpose(0, 3, 1, 2)


def depthwise_conv2d(x, w, b, stride):
    c = x.shape[1]
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding="SAME",
        feature_group_count=c,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b.reshape(1, -1, 1, 1)


def batchnorm(x, gamma, beta, mean, var, train: bool, momentum: float):
    if train:
        mu = x.mean(axis=(0, 2, 3))
        va = x.var(axis=(0, 2, 3))
        new_mean = momentum * mean + (1.0 - momentum) * mu
        new_var = momentum * var + (1.0 - momentum) * va
    else:
        mu, va = mean, var
        new_mean, new_var = mean, var
    inv = jax.lax.rsqrt(va + BN_EPS).reshape(1, -1, 1, 1)
    xn = (x - mu.reshape(1, -1, 1, 1)) * inv
    return gamma.reshape(1, -1, 1, 1) * xn + beta.reshape(1, -1, 1, 1), \
        (new_mean, new_var)


def forward(arch: dict, params: dict, stats: dict, x, train: bool,
            bn_momentum: float = 0.9):
    """x: f32[B, mel, frames] -> (logits f32[B, classes], new_stats dict)."""
    h = x[:, None, :, :]
    new_stats = {}

    def bn_block(h, tag):
        g, b = params[f"{tag}_gamma"], params[f"{tag}_beta"]
        m, v = stats[f"{tag}_mean"], stats[f"{tag}_var"]
        h, (nm, nv) = batchnorm(h, g, b, m, v, train, bn_momentum)
        new_stats[f"{tag}_mean"], new_stats[f"{tag}_var"] = nm, nv
        return jnp.maximum(h, 0.0)

    for i in range(len(arch["convs"])):
        n = i + 1
        stride = (1, 2) if i == 0 else (1, 1)
        if arch["type"] == "cnn" or i == 0:
            h = conv2d(h, params[f"conv{n}_w"], params[f"conv{n}_b"], stride)
            h = bn_block(h, f"bn{n}")
        else:
            h = depthwise_conv2d(h, params[f"dw{n}_w"], params[f"dw{n}_b"],
                                 stride)
            h = bn_block(h, f"bn{n}d")
            h = conv2d(h, params[f"pw{n}_w"], params[f"pw{n}_b"], (1, 1))
            h = bn_block(h, f"bn{n}p")
    pooled = h.mean(axis=(2, 3))
    logits = matmul_bias_act(pooled, params["fc_w"], params["fc_b"], "none")
    return logits, new_stats


# --------------------------------------------------------------------------
# AOT entry points (flat-vector signatures the rust runtime calls)
# --------------------------------------------------------------------------

def make_infer_fn(arch: dict, num_classes: int):
    pspec = param_spec(arch, num_classes)
    sspec = stats_spec(arch)

    def infer(params_flat, stats_flat, x):
        params = unflatten(params_flat, pspec)
        stats = unflatten(stats_flat, sspec)
        logits, _ = forward(arch, params, stats, x, train=False)
        return (logits,)

    return infer


def make_train_step(arch: dict, num_classes: int, cfg: dict):
    pspec = param_spec(arch, num_classes)
    sspec = stats_spec(arch)
    base_lr, gamma = cfg["base_lr"], cfg["gamma"]
    lr_step = cfg["lr_step"]
    b1, b2, eps = cfg["adam_beta1"], cfg["adam_beta2"], cfg["adam_eps"]
    momentum = cfg["bn_momentum"]

    def train_step(params_flat, stats_flat, m, v, step, x, y):
        def loss_fn(pf):
            params = unflatten(pf, pspec)
            stats = unflatten(stats_flat, sspec)
            logits, new_stats = forward(arch, params, stats, x, train=True,
                                        bn_momentum=momentum)
            logp = jax.nn.log_softmax(logits, axis=-1)
            yi = y.astype(jnp.int32)
            ce = -jnp.take_along_axis(logp, yi[:, None], axis=-1).mean()
            acc = (jnp.argmax(logits, -1) == yi).astype(jnp.float32).mean()
            return ce, (flatten(new_stats, sspec), acc)

        (loss, (new_stats_flat, acc)), grads = \
            jax.value_and_grad(loss_fn, has_aux=True)(params_flat)
        # Multi-step LR schedule (paper: drop to gamma x every lr_step iters).
        lr = base_lr * jnp.power(gamma, jnp.floor(step / lr_step))
        t = step + 1.0
        m_new = b1 * m + (1.0 - b1) * grads
        v_new = b2 * v + (1.0 - b2) * grads * grads
        m_hat = m_new / (1.0 - jnp.power(b1, t))
        v_hat = v_new / (1.0 - jnp.power(b2, t))
        params_new = params_flat - lr * m_hat / (jnp.sqrt(v_hat) + eps)
        return params_new, new_stats_flat, m_new, v_new, loss, acc

    return train_step


def state_sizes(arch: dict, num_classes: int):
    """(n_params, n_stats) flat-vector lengths."""
    _, p = layout(param_spec(arch, num_classes))
    _, s = layout(stats_spec(arch))
    return p, s
