"""AOT compiler: lower L2 graphs (which embed the L1 pallas kernels) to HLO
*text* artifacts for the rust PJRT runtime, plus a JSON manifest.

HLO text — NOT serialized HloModuleProto — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo.

Emitted per run (default: every arch in configs/kws_archs.json):
  artifacts/mfcc_b{B}.hlo.txt              MFCC front-end (paper §4)
  artifacts/{arch}_infer_b{B}.hlo.txt      inference graphs (serving buckets)
  artifacts/{arch}_train_b{B}.hlo.txt      Adam train step (paper §5.1)
  artifacts/{arch}_init.bin / _init_stats.bin   He-init flat state (f32 LE)
  artifacts/manifest.json                  graph/arch metadata + state layout

NAS mode (invoked by the rust NAS tool as a pipeline *tool* — python stays on
the compile path, never the request path):
  python -m compile.aot --arch-json '{"type":"cnn","convs":[...]}' \
      --name cand7 --out-dir ../artifacts/nas --train-batch 32
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import features, model

MFCC_BATCHES = [1, 8, 32, 64]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which the rust-side HLO text parser
    # reads back as zeros — silently zeroing the MFCC DFT bases and framing
    # indices. (Found the hard way; see EXPERIMENTS.md.)
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def io_meta(shapes_in, shapes_out):
    return ([{"name": n, "shape": list(s), "dtype": "f32"} for n, s in shapes_in],
            [{"name": n, "shape": list(s), "dtype": "f32"} for n, s in shapes_out])


def emit(out_dir, name, text):
    path = os.path.join(out_dir, name + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")
    return name + ".hlo.txt"


def lower_mfcc(batch):
    def fn(audio):
        return (features.mfcc(audio),)
    return jax.jit(fn).lower(spec((batch, features.SAMPLE_RATE)))


def lower_infer(arch, num_classes, n_params, n_stats, batch, mel, frames):
    fn = model.make_infer_fn(arch, num_classes)
    return jax.jit(fn).lower(
        spec((n_params,)), spec((n_stats,)), spec((batch, mel, frames)))


def lower_train(arch, num_classes, n_params, n_stats, batch, mel, frames, cfg):
    fn = model.make_train_step(arch, num_classes, cfg)
    return jax.jit(fn).lower(
        spec((n_params,)), spec((n_stats,)), spec((n_params,)),
        spec((n_params,)), spec(()), spec((batch, mel, frames)),
        spec((batch,)))


def arch_entry(arch, num_classes, out_dir, name, seed=0):
    """Init-state files + layout metadata for one architecture."""
    p_layout, n_params = model.layout(model.param_spec(arch, num_classes))
    s_layout, n_stats = model.layout(model.stats_spec(arch))
    params, stats = model.init_params(arch, num_classes, seed=seed)
    pflat = np.asarray(model.flatten(params, model.param_spec(arch, num_classes)))
    sflat = np.asarray(model.flatten(stats, model.stats_spec(arch)))
    init_f = f"{name}_init.bin"
    init_s = f"{name}_init_stats.bin"
    pflat.astype("<f4").tofile(os.path.join(out_dir, init_f))
    sflat.astype("<f4").tofile(os.path.join(out_dir, init_s))
    return {
        "type": arch["type"], "convs": arch["convs"],
        "n_params": n_params, "n_stats": n_stats,
        "param_layout": p_layout, "stats_layout": s_layout,
        "init_file": init_f, "init_stats_file": init_s,
    }


def build_arch(cfgall, arch, name, out_dir, infer_batches, train_batch):
    nc = cfgall["num_classes"]
    mel = cfgall["input"]["mel_bands"]
    frames = cfgall["input"]["frames"]
    entry = arch_entry(arch, nc, out_dir, name)
    n_params, n_stats = entry["n_params"], entry["n_stats"]
    graphs = []
    for b in infer_batches:
        text = to_hlo_text(lower_infer(arch, nc, n_params, n_stats, b, mel,
                                       frames))
        fname = emit(out_dir, f"{name}_infer_b{b}", text)
        ins, outs = io_meta(
            [("params", (n_params,)), ("stats", (n_stats,)),
             ("x", (b, mel, frames))],
            [("logits", (b, nc))])
        graphs.append({"name": f"{name}_infer_b{b}", "file": fname,
                       "kind": "infer", "arch": name, "batch": b,
                       "inputs": ins, "outputs": outs})
    if train_batch:
        b = train_batch
        text = to_hlo_text(lower_train(arch, nc, n_params, n_stats, b, mel,
                                       frames, cfgall["train"]))
        fname = emit(out_dir, f"{name}_train_b{b}", text)
        ins, outs = io_meta(
            [("params", (n_params,)), ("stats", (n_stats,)),
             ("m", (n_params,)), ("v", (n_params,)), ("step", ()),
             ("x", (b, mel, frames)), ("y", (b,))],
            [("params", (n_params,)), ("stats", (n_stats,)),
             ("m", (n_params,)), ("v", (n_params,)), ("loss", ()),
             ("acc", ())])
        graphs.append({"name": f"{name}_train_b{b}", "file": fname,
                       "kind": "train", "arch": name, "batch": b,
                       "inputs": ins, "outputs": outs})
    return entry, graphs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--config", default=model.CONFIG_PATH)
    ap.add_argument("--archs", default="", help="comma list; default all")
    ap.add_argument("--arch-json", default="", help="single inline arch (NAS)")
    ap.add_argument("--name", default="cand", help="name for --arch-json")
    ap.add_argument("--train-batch", type=int, default=0,
                    help="override train batch (0 = config value)")
    ap.add_argument("--infer-batches", default="", help="override, comma list")
    ap.add_argument("--no-mfcc", action="store_true")
    args = ap.parse_args()

    cfgall = model.load_config(args.config)
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    train_batch = args.train_batch or cfgall["train"]["batch"]
    infer_batches = ([int(b) for b in args.infer_batches.split(",") if b]
                     or cfgall["infer_batches"])

    manifest = {
        "version": 1,
        "mel_bands": cfgall["input"]["mel_bands"],
        "frames": cfgall["input"]["frames"],
        "samples": cfgall["input"]["samples"],
        "sample_rate": cfgall["input"]["sample_rate"],
        "num_classes": cfgall["num_classes"],
        "classes": cfgall["classes"],
        "train_cfg": dict(cfgall["train"], batch=train_batch),
        "graphs": [], "archs": {},
    }

    if args.arch_json:
        # NAS tool path: one candidate, its own manifest, no MFCC graphs.
        arch = json.loads(args.arch_json)
        entry, graphs = build_arch(cfgall, arch, args.name, out_dir,
                                   infer_batches, train_batch)
        manifest["archs"][args.name] = entry
        manifest["graphs"] = graphs
        mpath = os.path.join(out_dir, f"{args.name}.manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        print(f"  wrote {mpath}")
        return

    if not args.no_mfcc:
        for b in MFCC_BATCHES:
            text = to_hlo_text(lower_mfcc(b))
            fname = emit(out_dir, f"mfcc_b{b}", text)
            ins, outs = io_meta(
                [("audio", (b, features.SAMPLE_RATE))],
                [("mfcc", (b, features.N_MELS, features.N_FRAMES))])
            manifest["graphs"].append(
                {"name": f"mfcc_b{b}", "file": fname, "kind": "mfcc",
                 "batch": b, "inputs": ins, "outputs": outs})

    selected = [a for a in args.archs.split(",") if a] or \
        list(cfgall["archs"].keys())
    for name in selected:
        arch = cfgall["archs"][name]
        print(f"arch {name}:")
        entry, graphs = build_arch(cfgall, arch, name, out_dir, infer_batches,
                                   train_batch)
        manifest["archs"][name] = entry
        manifest["graphs"].extend(graphs)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['graphs'])} graphs, "
          f"{len(manifest['archs'])} archs -> {out_dir}/manifest.json")


if __name__ == "__main__":
    sys.exit(main())
